// @meta name session_step_q
// @meta states 1472
// @meta instrs 1045
// @io input 0 mem r0 dtype i32 width 8 shape 1x15
// @io input 1 mem r1 dtype i32 width 8 shape 1x15
// @io input 2 mem r2 dtype i32 width 8 shape 1x15
// @io input 3 mem r3 dtype i32 width 8 shape 1x15
// @io input 4 mem r4 dtype i32 width 8 shape 1x15
// @io input 5 mem r5 dtype i32 width 8 shape 1x15
// @io input 6 mem r6 dtype i32 width 32 shape 1
// @io input 7 mem r7 dtype i32 width 32 shape 1
// @io input 8 mem r8 dtype i32 width 32 shape 1
// @io input 9 mem r9 dtype i32 width 32 shape 1
// @io input 10 mem r10 dtype i32 width 32 shape 1
// @io input 11 mem r11 dtype i32 width 32 shape 1
// @io input 12 mem r12 dtype i32 width 24 shape 1x30
// @io input 13 mem r13 dtype i32 width 9 shape 1
// @io input 14 mem r14 dtype i32 width 32 shape 1
// @io input 15 mem r15 dtype i1 width 1 shape 1
// @io input 16 mem r16 dtype i32 width 8 shape 1x160
// @io input 17 mem r17 dtype i32 width 9 shape 1
// @io output 0 mem r131 dtype i32 width 8 shape 1x15
// @io output 1 mem r329 dtype i32 width 8 shape 1x15
// @io output 2 mem r526 dtype i32 width 8 shape 1x15
// @io output 3 mem r723 dtype i32 width 8 shape 1x15
// @io output 4 mem r920 dtype i32 width 8 shape 1x15
// @io output 5 mem r1117 dtype i32 width 8 shape 1x15
// @io output 6 mem r132 dtype i32 width 32 shape 1
// @io output 7 mem r330 dtype i32 width 32 shape 1
// @io output 8 mem r527 dtype i32 width 32 shape 1
// @io output 9 mem r724 dtype i32 width 32 shape 1
// @io output 10 mem r921 dtype i32 width 32 shape 1
// @io output 11 mem r1118 dtype i32 width 32 shape 1
// @io output 12 mem r1120 dtype i32 width 24 shape 1x30
// @io output 13 mem r28 dtype i32 width 9 shape 1
// @io output 14 mem r1121 dtype i32 width 32 shape 1
// @io output 15 mem r15 dtype i1 width 1 shape 1
// @io output 16 mem r1269 dtype i32 width 11 shape 1x10
// @io output 17 mem r1159 dtype i32 width 8 shape 1x30
// @rom rom0_c file rom/rom0_c.mem words 80
// @rom rom1_c file rom/rom1_c.mem words 6
// @rom rom2_c file rom/rom2_c.mem words 30
// @rom rom3_c file rom/rom3_c.mem words 30
// @rom rom4_c file rom/rom4_c.mem words 30
// @rom rom5_c file rom/rom5_c.mem words 300
// @rom rom6_c file rom/rom6_c.mem words 300
// @rom rom7_c file rom/rom7_c.mem words 10
// @rom rom8_lit file rom/rom8_lit.mem words 1
// @rom rom9_lit file rom/rom9_lit.mem words 1
// @rom rom10_lit file rom/rom10_lit.mem words 1
// @rom rom11_lit file rom/rom11_lit.mem words 1
// @rom rom12_lit file rom/rom12_lit.mem words 1
// @rom rom13_lit file rom/rom13_lit.mem words 1
// @rom rom14_lit file rom/rom14_lit.mem words 1
// @rom rom15_lit file rom/rom15_lit.mem words 1
// @rom rom16_lit file rom/rom16_lit.mem words 1
// @rom rom17_lit file rom/rom17_lit.mem words 1
// @rom rom18_lit file rom/rom18_lit.mem words 1
// @rom rom19_lit file rom/rom19_lit.mem words 1
// @rom rom20_lit file rom/rom20_lit.mem words 1
// @rom rom21_lit file rom/rom21_lit.mem words 1
// @rom rom22_lit file rom/rom22_lit.mem words 1
// @rom rom23_lit file rom/rom23_lit.mem words 1
// @rom rom24_lit file rom/rom24_lit.mem words 1
// @rom rom25_lit file rom/rom25_lit.mem words 1
// @rom rom26_lit file rom/rom26_lit.mem words 1
// @rom rom27_lit file rom/rom27_lit.mem words 1
// @rom rom28_lit file rom/rom28_lit.mem words 1
// @rom rom29_lit file rom/rom29_lit.mem words 1
// @rom rom30_lit file rom/rom30_lit.mem words 1
// @rom rom31_lit file rom/rom31_lit.mem words 1
// @trace state 1 instr 0 op abs dests r26
// @trace state 3 instr 1 op reduce_max dests r27
// @trace state 4 instr 2 op max dests r28
// @trace state 6 instr 3 op concat dests r29
// @trace state 7 instr 4 op shl dests r31
// @trace state 8 instr 5 op mov dests r32
// @trace state 9 instr 6 op rev dests r33
// @trace state 10 instr 7 op reshape dests r34
// @trace state 11 instr 8 op iota dests r35
// @trace state 12 instr 9 op broadcast dests r36
// @trace state 13 instr 10 op iota dests r37
// @trace state 14 instr 11 op broadcast dests r38
// @trace state 15 instr 12 op add dests r39
// @trace state 16 instr 13 op lt dests r41
// @trace state 17 instr 14 op add dests r43
// @trace state 18 instr 15 op select_n dests r44
// @trace state 19 instr 16 op broadcast dests r45
// @trace state 20 instr 17 op gather dests r46
// @trace state 21 instr 18 op broadcast dests r47
// @trace state 22 instr 19 op add dests r48
// @trace state 23 instr 20 op convert dests r51
// @trace state 24 instr 21 op max dests r52
// @trace state 25 instr 22 op convert dests r53
// @trace state 26 instr 23 op min dests r54
// @trace state 27 instr 24 op sub dests r55
// @trace state 28 instr 25 op convert dests r56
// @trace state 29 instr 26 op max dests r57
// @trace state 30 instr 27 op convert dests r58
// @trace state 31 instr 28 op min dests r59
// @trace state 32 instr 29 op abs dests r60
// @trace state 34 instr 30 op reduce_max dests r61
// @trace state 35 instr 31 op sub dests r63
// @trace state 43 instr 33 op add dests r69
// @trace state 44 instr 34 op add dests r70
// @trace state 45 instr 35 op shra dests r71
// @trace state 46 instr 36 op broadcast dests r72
// @trace state 47 instr 37 op sub dests r73
// @trace state 48 instr 38 op max dests r74
// @trace state 50 instr 39 op reduce_sum dests r75
// @trace state 51 instr 40 op neg dests r76
// @trace state 52 instr 41 op broadcast dests r77
// @trace state 53 instr 42 op sub dests r78
// @trace state 54 instr 43 op max dests r79
// @trace state 56 instr 44 op reduce_sum dests r80
// @trace state 57 instr 45 op add dests r81
// @trace state 58 instr 46 op gt dests r82
// @trace state 59 instr 47 op select_n dests r83
// @trace state 60 instr 48 op select_n dests r84
// @trace state 67 instr 32 op loop dests r85 r86 r87
// @trace state 68 instr 49 op abs dests r88
// @trace state 70 instr 50 op reduce_max dests r89
// @trace state 71 instr 51 op sub dests r90
// @trace state 79 instr 53 op add dests r96
// @trace state 80 instr 54 op add dests r97
// @trace state 81 instr 55 op shra dests r98
// @trace state 82 instr 56 op broadcast dests r99
// @trace state 83 instr 57 op sub dests r100
// @trace state 84 instr 58 op max dests r101
// @trace state 86 instr 59 op reduce_sum dests r102
// @trace state 87 instr 60 op neg dests r103
// @trace state 88 instr 61 op broadcast dests r104
// @trace state 89 instr 62 op sub dests r105
// @trace state 90 instr 63 op max dests r106
// @trace state 92 instr 64 op reduce_sum dests r107
// @trace state 93 instr 65 op add dests r108
// @trace state 94 instr 66 op gt dests r109
// @trace state 95 instr 67 op select_n dests r110
// @trace state 96 instr 68 op select_n dests r111
// @trace state 103 instr 52 op loop dests r112 r113 r114
// @trace state 104 instr 69 op sub dests r115
// @trace state 105 instr 70 op transpose dests r116
// @trace state 106 instr 71 op broadcast dests r117
// @trace state 107 instr 72 op max dests r118
// @trace state 108 instr 73 op iota dests r119
// @trace state 109 instr 74 op broadcast dests r120
// @trace state 110 instr 75 op lt dests r121
// @trace state 111 instr 76 op convert dests r122
// @trace state 112 instr 77 op broadcast dests r123
// @trace state 113 instr 78 op select_n dests r124
// @trace state 115 instr 79 op reduce_sum dests r125
// @trace state 116 instr 80 op shl dests r126
// @trace state 117 instr 81 op lt dests r127
// @trace state 118 instr 82 op add dests r128
// @trace state 119 instr 83 op select_n dests r129
// @trace state 120 instr 84 op broadcast dests r130
// @trace state 121 instr 85 op gather dests r131
// @trace state 122 instr 86 op add dests r132
// @trace state 123 instr 87 op and dests r133
// @trace state 124 instr 88 op slice dests r134
// @trace state 125 instr 89 op shl dests r135
// @trace state 126 instr 90 op convert dests r136
// @trace state 128 instr 91 op pad dests r137
// @trace state 129 instr 92 op iota dests r138
// @trace state 130 instr 93 op shl dests r139
// @trace state 131 instr 94 op broadcast dests r140
// @trace state 132 instr 95 op iota dests r141
// @trace state 133 instr 96 op broadcast dests r142
// @trace state 134 instr 97 op add dests r143
// @trace state 135 instr 98 op broadcast dests r144
// @trace state 136 instr 99 op broadcast dests r145
// @trace state 137 instr 100 op add dests r146
// @trace state 138 instr 101 op lt dests r147
// @trace state 139 instr 102 op add dests r149
// @trace state 140 instr 103 op select_n dests r150
// @trace state 141 instr 104 op broadcast dests r151
// @trace state 142 instr 105 op gather dests r152
// @trace state 143 instr 106 op mov dests r153
// @trace state 144 instr 107 op broadcast dests r154
// @trace state 145 instr 108 op add dests r155
// @trace state 146 instr 109 op convert dests r156
// @trace state 147 instr 110 op max dests r157
// @trace state 148 instr 111 op convert dests r158
// @trace state 149 instr 112 op min dests r159
// @trace state 150 instr 113 op broadcast dests r160
// @trace state 151 instr 114 op sub dests r161
// @trace state 152 instr 115 op convert dests r162
// @trace state 153 instr 116 op max dests r163
// @trace state 154 instr 117 op convert dests r164
// @trace state 155 instr 118 op min dests r165
// @trace state 156 instr 119 op abs dests r166
// @trace state 158 instr 120 op reduce_max dests r167
// @trace state 159 instr 121 op sub dests r168
// @trace state 167 instr 123 op add dests r174
// @trace state 168 instr 124 op add dests r175
// @trace state 169 instr 125 op shra dests r176
// @trace state 170 instr 126 op broadcast dests r177
// @trace state 171 instr 127 op sub dests r178
// @trace state 172 instr 128 op max dests r179
// @trace state 174 instr 129 op reduce_sum dests r180
// @trace state 175 instr 130 op neg dests r181
// @trace state 176 instr 131 op broadcast dests r182
// @trace state 177 instr 132 op sub dests r183
// @trace state 178 instr 133 op max dests r184
// @trace state 180 instr 134 op reduce_sum dests r185
// @trace state 181 instr 135 op add dests r186
// @trace state 182 instr 136 op gt dests r187
// @trace state 183 instr 137 op select_n dests r188
// @trace state 184 instr 138 op select_n dests r189
// @trace state 191 instr 122 op loop dests r190 r191 r192
// @trace state 192 instr 139 op abs dests r193
// @trace state 194 instr 140 op reduce_max dests r194
// @trace state 195 instr 141 op sub dests r195
// @trace state 203 instr 143 op add dests r201
// @trace state 204 instr 144 op add dests r202
// @trace state 205 instr 145 op shra dests r203
// @trace state 206 instr 146 op broadcast dests r204
// @trace state 207 instr 147 op sub dests r205
// @trace state 208 instr 148 op max dests r206
// @trace state 210 instr 149 op reduce_sum dests r207
// @trace state 211 instr 150 op neg dests r208
// @trace state 212 instr 151 op broadcast dests r209
// @trace state 213 instr 152 op sub dests r210
// @trace state 214 instr 153 op max dests r211
// @trace state 216 instr 154 op reduce_sum dests r212
// @trace state 217 instr 155 op add dests r213
// @trace state 218 instr 156 op gt dests r214
// @trace state 219 instr 157 op select_n dests r215
// @trace state 220 instr 158 op select_n dests r216
// @trace state 227 instr 142 op loop dests r217 r218 r219
// @trace state 228 instr 159 op sub dests r220
// @trace state 229 instr 160 op shra dests r221
// @trace state 230 instr 161 op convert dests r224
// @trace state 231 instr 162 op max dests r225
// @trace state 232 instr 163 op convert dests r226
// @trace state 233 instr 164 op min dests r227
// @trace state 234 instr 165 op sub dests r228
// @trace state 235 instr 166 op add dests r229
// @trace state 236 instr 167 op max dests r230
// @trace state 237 instr 168 op shra dests r231
// @trace state 239 instr 169 op concat dests r232
// @trace state 240 instr 170 op shl dests r233
// @trace state 241 instr 171 op mov dests r234
// @trace state 242 instr 172 op rev dests r235
// @trace state 243 instr 173 op reshape dests r236
// @trace state 244 instr 174 op iota dests r237
// @trace state 245 instr 175 op broadcast dests r238
// @trace state 246 instr 176 op iota dests r239
// @trace state 247 instr 177 op broadcast dests r240
// @trace state 248 instr 178 op add dests r241
// @trace state 249 instr 179 op lt dests r242
// @trace state 250 instr 180 op add dests r244
// @trace state 251 instr 181 op select_n dests r245
// @trace state 252 instr 182 op broadcast dests r246
// @trace state 253 instr 183 op gather dests r247
// @trace state 254 instr 184 op broadcast dests r248
// @trace state 255 instr 185 op add dests r249
// @trace state 256 instr 186 op convert dests r250
// @trace state 257 instr 187 op max dests r251
// @trace state 258 instr 188 op convert dests r252
// @trace state 259 instr 189 op min dests r253
// @trace state 260 instr 190 op sub dests r254
// @trace state 261 instr 191 op convert dests r255
// @trace state 262 instr 192 op max dests r256
// @trace state 263 instr 193 op convert dests r257
// @trace state 264 instr 194 op min dests r258
// @trace state 265 instr 195 op abs dests r259
// @trace state 267 instr 196 op reduce_max dests r260
// @trace state 268 instr 197 op sub dests r261
// @trace state 276 instr 199 op add dests r267
// @trace state 277 instr 200 op add dests r268
// @trace state 278 instr 201 op shra dests r269
// @trace state 279 instr 202 op broadcast dests r270
// @trace state 280 instr 203 op sub dests r271
// @trace state 281 instr 204 op max dests r272
// @trace state 283 instr 205 op reduce_sum dests r273
// @trace state 284 instr 206 op neg dests r274
// @trace state 285 instr 207 op broadcast dests r275
// @trace state 286 instr 208 op sub dests r276
// @trace state 287 instr 209 op max dests r277
// @trace state 289 instr 210 op reduce_sum dests r278
// @trace state 290 instr 211 op add dests r279
// @trace state 291 instr 212 op gt dests r280
// @trace state 292 instr 213 op select_n dests r281
// @trace state 293 instr 214 op select_n dests r282
// @trace state 300 instr 198 op loop dests r283 r284 r285
// @trace state 301 instr 215 op abs dests r286
// @trace state 303 instr 216 op reduce_max dests r287
// @trace state 304 instr 217 op sub dests r288
// @trace state 312 instr 219 op add dests r294
// @trace state 313 instr 220 op add dests r295
// @trace state 314 instr 221 op shra dests r296
// @trace state 315 instr 222 op broadcast dests r297
// @trace state 316 instr 223 op sub dests r298
// @trace state 317 instr 224 op max dests r299
// @trace state 319 instr 225 op reduce_sum dests r300
// @trace state 320 instr 226 op neg dests r301
// @trace state 321 instr 227 op broadcast dests r302
// @trace state 322 instr 228 op sub dests r303
// @trace state 323 instr 229 op max dests r304
// @trace state 325 instr 230 op reduce_sum dests r305
// @trace state 326 instr 231 op add dests r306
// @trace state 327 instr 232 op gt dests r307
// @trace state 328 instr 233 op select_n dests r308
// @trace state 329 instr 234 op select_n dests r309
// @trace state 336 instr 218 op loop dests r310 r311 r312
// @trace state 337 instr 235 op sub dests r313
// @trace state 338 instr 236 op transpose dests r314
// @trace state 339 instr 237 op broadcast dests r315
// @trace state 340 instr 238 op max dests r316
// @trace state 341 instr 239 op iota dests r317
// @trace state 342 instr 240 op broadcast dests r318
// @trace state 343 instr 241 op lt dests r319
// @trace state 344 instr 242 op convert dests r320
// @trace state 345 instr 243 op broadcast dests r321
// @trace state 346 instr 244 op select_n dests r322
// @trace state 348 instr 245 op reduce_sum dests r323
// @trace state 349 instr 246 op shl dests r324
// @trace state 350 instr 247 op lt dests r325
// @trace state 351 instr 248 op add dests r326
// @trace state 352 instr 249 op select_n dests r327
// @trace state 353 instr 250 op broadcast dests r328
// @trace state 354 instr 251 op gather dests r329
// @trace state 355 instr 252 op add dests r330
// @trace state 356 instr 253 op and dests r331
// @trace state 357 instr 254 op slice dests r332
// @trace state 358 instr 255 op shl dests r333
// @trace state 359 instr 256 op convert dests r334
// @trace state 361 instr 257 op pad dests r335
// @trace state 362 instr 258 op iota dests r336
// @trace state 363 instr 259 op shl dests r337
// @trace state 364 instr 260 op broadcast dests r338
// @trace state 365 instr 261 op iota dests r339
// @trace state 366 instr 262 op broadcast dests r340
// @trace state 367 instr 263 op add dests r341
// @trace state 368 instr 264 op broadcast dests r342
// @trace state 369 instr 265 op broadcast dests r343
// @trace state 370 instr 266 op add dests r344
// @trace state 371 instr 267 op lt dests r345
// @trace state 372 instr 268 op add dests r347
// @trace state 373 instr 269 op select_n dests r348
// @trace state 374 instr 270 op broadcast dests r349
// @trace state 375 instr 271 op gather dests r350
// @trace state 376 instr 272 op mov dests r351
// @trace state 377 instr 273 op broadcast dests r352
// @trace state 378 instr 274 op add dests r353
// @trace state 379 instr 275 op convert dests r354
// @trace state 380 instr 276 op max dests r355
// @trace state 381 instr 277 op convert dests r356
// @trace state 382 instr 278 op min dests r357
// @trace state 383 instr 279 op broadcast dests r358
// @trace state 384 instr 280 op sub dests r359
// @trace state 385 instr 281 op convert dests r360
// @trace state 386 instr 282 op max dests r361
// @trace state 387 instr 283 op convert dests r362
// @trace state 388 instr 284 op min dests r363
// @trace state 389 instr 285 op abs dests r364
// @trace state 391 instr 286 op reduce_max dests r365
// @trace state 392 instr 287 op sub dests r366
// @trace state 400 instr 289 op add dests r372
// @trace state 401 instr 290 op add dests r373
// @trace state 402 instr 291 op shra dests r374
// @trace state 403 instr 292 op broadcast dests r375
// @trace state 404 instr 293 op sub dests r376
// @trace state 405 instr 294 op max dests r377
// @trace state 407 instr 295 op reduce_sum dests r378
// @trace state 408 instr 296 op neg dests r379
// @trace state 409 instr 297 op broadcast dests r380
// @trace state 410 instr 298 op sub dests r381
// @trace state 411 instr 299 op max dests r382
// @trace state 413 instr 300 op reduce_sum dests r383
// @trace state 414 instr 301 op add dests r384
// @trace state 415 instr 302 op gt dests r385
// @trace state 416 instr 303 op select_n dests r386
// @trace state 417 instr 304 op select_n dests r387
// @trace state 424 instr 288 op loop dests r388 r389 r390
// @trace state 425 instr 305 op abs dests r391
// @trace state 427 instr 306 op reduce_max dests r392
// @trace state 428 instr 307 op sub dests r393
// @trace state 436 instr 309 op add dests r399
// @trace state 437 instr 310 op add dests r400
// @trace state 438 instr 311 op shra dests r401
// @trace state 439 instr 312 op broadcast dests r402
// @trace state 440 instr 313 op sub dests r403
// @trace state 441 instr 314 op max dests r404
// @trace state 443 instr 315 op reduce_sum dests r405
// @trace state 444 instr 316 op neg dests r406
// @trace state 445 instr 317 op broadcast dests r407
// @trace state 446 instr 318 op sub dests r408
// @trace state 447 instr 319 op max dests r409
// @trace state 449 instr 320 op reduce_sum dests r410
// @trace state 450 instr 321 op add dests r411
// @trace state 451 instr 322 op gt dests r412
// @trace state 452 instr 323 op select_n dests r413
// @trace state 453 instr 324 op select_n dests r414
// @trace state 460 instr 308 op loop dests r415 r416 r417
// @trace state 461 instr 325 op sub dests r418
// @trace state 462 instr 326 op shra dests r419
// @trace state 463 instr 327 op convert dests r420
// @trace state 464 instr 328 op max dests r421
// @trace state 465 instr 329 op convert dests r422
// @trace state 466 instr 330 op min dests r423
// @trace state 467 instr 331 op sub dests r424
// @trace state 468 instr 332 op add dests r425
// @trace state 469 instr 333 op max dests r426
// @trace state 470 instr 334 op shra dests r427
// @trace state 472 instr 335 op concat dests r428
// @trace state 473 instr 336 op shl dests r429
// @trace state 474 instr 337 op mov dests r430
// @trace state 475 instr 338 op rev dests r431
// @trace state 476 instr 339 op reshape dests r432
// @trace state 477 instr 340 op iota dests r433
// @trace state 478 instr 341 op broadcast dests r434
// @trace state 479 instr 342 op iota dests r435
// @trace state 480 instr 343 op broadcast dests r436
// @trace state 481 instr 344 op add dests r437
// @trace state 482 instr 345 op lt dests r438
// @trace state 483 instr 346 op add dests r440
// @trace state 484 instr 347 op select_n dests r441
// @trace state 485 instr 348 op broadcast dests r442
// @trace state 486 instr 349 op gather dests r443
// @trace state 487 instr 350 op broadcast dests r444
// @trace state 488 instr 351 op add dests r445
// @trace state 489 instr 352 op convert dests r446
// @trace state 490 instr 353 op max dests r447
// @trace state 491 instr 354 op convert dests r448
// @trace state 492 instr 355 op min dests r449
// @trace state 493 instr 356 op sub dests r450
// @trace state 494 instr 357 op convert dests r451
// @trace state 495 instr 358 op max dests r452
// @trace state 496 instr 359 op convert dests r453
// @trace state 497 instr 360 op min dests r454
// @trace state 498 instr 361 op abs dests r455
// @trace state 500 instr 362 op reduce_max dests r456
// @trace state 501 instr 363 op sub dests r457
// @trace state 509 instr 365 op add dests r463
// @trace state 510 instr 366 op add dests r464
// @trace state 511 instr 367 op shra dests r465
// @trace state 512 instr 368 op broadcast dests r466
// @trace state 513 instr 369 op sub dests r467
// @trace state 514 instr 370 op max dests r468
// @trace state 516 instr 371 op reduce_sum dests r469
// @trace state 517 instr 372 op neg dests r470
// @trace state 518 instr 373 op broadcast dests r471
// @trace state 519 instr 374 op sub dests r472
// @trace state 520 instr 375 op max dests r473
// @trace state 522 instr 376 op reduce_sum dests r474
// @trace state 523 instr 377 op add dests r475
// @trace state 524 instr 378 op gt dests r476
// @trace state 525 instr 379 op select_n dests r477
// @trace state 526 instr 380 op select_n dests r478
// @trace state 533 instr 364 op loop dests r479 r480 r481
// @trace state 534 instr 381 op abs dests r482
// @trace state 536 instr 382 op reduce_max dests r483
// @trace state 537 instr 383 op sub dests r484
// @trace state 545 instr 385 op add dests r490
// @trace state 546 instr 386 op add dests r491
// @trace state 547 instr 387 op shra dests r492
// @trace state 548 instr 388 op broadcast dests r493
// @trace state 549 instr 389 op sub dests r494
// @trace state 550 instr 390 op max dests r495
// @trace state 552 instr 391 op reduce_sum dests r496
// @trace state 553 instr 392 op neg dests r497
// @trace state 554 instr 393 op broadcast dests r498
// @trace state 555 instr 394 op sub dests r499
// @trace state 556 instr 395 op max dests r500
// @trace state 558 instr 396 op reduce_sum dests r501
// @trace state 559 instr 397 op add dests r502
// @trace state 560 instr 398 op gt dests r503
// @trace state 561 instr 399 op select_n dests r504
// @trace state 562 instr 400 op select_n dests r505
// @trace state 569 instr 384 op loop dests r506 r507 r508
// @trace state 570 instr 401 op sub dests r509
// @trace state 571 instr 402 op transpose dests r510
// @trace state 572 instr 403 op broadcast dests r511
// @trace state 573 instr 404 op max dests r512
// @trace state 574 instr 405 op iota dests r513
// @trace state 575 instr 406 op broadcast dests r514
// @trace state 576 instr 407 op lt dests r515
// @trace state 577 instr 408 op convert dests r516
// @trace state 578 instr 409 op broadcast dests r517
// @trace state 579 instr 410 op select_n dests r518
// @trace state 581 instr 411 op reduce_sum dests r519
// @trace state 582 instr 412 op shl dests r521
// @trace state 583 instr 413 op lt dests r522
// @trace state 584 instr 414 op add dests r523
// @trace state 585 instr 415 op select_n dests r524
// @trace state 586 instr 416 op broadcast dests r525
// @trace state 587 instr 417 op gather dests r526
// @trace state 588 instr 418 op add dests r527
// @trace state 589 instr 419 op and dests r528
// @trace state 590 instr 420 op slice dests r529
// @trace state 591 instr 421 op shl dests r530
// @trace state 592 instr 422 op convert dests r531
// @trace state 594 instr 423 op pad dests r532
// @trace state 595 instr 424 op iota dests r533
// @trace state 596 instr 425 op shl dests r534
// @trace state 597 instr 426 op broadcast dests r535
// @trace state 598 instr 427 op iota dests r536
// @trace state 599 instr 428 op broadcast dests r537
// @trace state 600 instr 429 op add dests r538
// @trace state 601 instr 430 op broadcast dests r539
// @trace state 602 instr 431 op broadcast dests r540
// @trace state 603 instr 432 op add dests r541
// @trace state 604 instr 433 op lt dests r542
// @trace state 605 instr 434 op add dests r544
// @trace state 606 instr 435 op select_n dests r545
// @trace state 607 instr 436 op broadcast dests r546
// @trace state 608 instr 437 op gather dests r547
// @trace state 609 instr 438 op mov dests r548
// @trace state 610 instr 439 op broadcast dests r549
// @trace state 611 instr 440 op add dests r550
// @trace state 612 instr 441 op convert dests r551
// @trace state 613 instr 442 op max dests r552
// @trace state 614 instr 443 op convert dests r553
// @trace state 615 instr 444 op min dests r554
// @trace state 616 instr 445 op broadcast dests r555
// @trace state 617 instr 446 op sub dests r556
// @trace state 618 instr 447 op convert dests r557
// @trace state 619 instr 448 op max dests r558
// @trace state 620 instr 449 op convert dests r559
// @trace state 621 instr 450 op min dests r560
// @trace state 622 instr 451 op abs dests r561
// @trace state 624 instr 452 op reduce_max dests r562
// @trace state 625 instr 453 op sub dests r563
// @trace state 633 instr 455 op add dests r569
// @trace state 634 instr 456 op add dests r570
// @trace state 635 instr 457 op shra dests r571
// @trace state 636 instr 458 op broadcast dests r572
// @trace state 637 instr 459 op sub dests r573
// @trace state 638 instr 460 op max dests r574
// @trace state 640 instr 461 op reduce_sum dests r575
// @trace state 641 instr 462 op neg dests r576
// @trace state 642 instr 463 op broadcast dests r577
// @trace state 643 instr 464 op sub dests r578
// @trace state 644 instr 465 op max dests r579
// @trace state 646 instr 466 op reduce_sum dests r580
// @trace state 647 instr 467 op add dests r581
// @trace state 648 instr 468 op gt dests r582
// @trace state 649 instr 469 op select_n dests r583
// @trace state 650 instr 470 op select_n dests r584
// @trace state 657 instr 454 op loop dests r585 r586 r587
// @trace state 658 instr 471 op abs dests r588
// @trace state 660 instr 472 op reduce_max dests r589
// @trace state 661 instr 473 op sub dests r590
// @trace state 669 instr 475 op add dests r596
// @trace state 670 instr 476 op add dests r597
// @trace state 671 instr 477 op shra dests r598
// @trace state 672 instr 478 op broadcast dests r599
// @trace state 673 instr 479 op sub dests r600
// @trace state 674 instr 480 op max dests r601
// @trace state 676 instr 481 op reduce_sum dests r602
// @trace state 677 instr 482 op neg dests r603
// @trace state 678 instr 483 op broadcast dests r604
// @trace state 679 instr 484 op sub dests r605
// @trace state 680 instr 485 op max dests r606
// @trace state 682 instr 486 op reduce_sum dests r607
// @trace state 683 instr 487 op add dests r608
// @trace state 684 instr 488 op gt dests r609
// @trace state 685 instr 489 op select_n dests r610
// @trace state 686 instr 490 op select_n dests r611
// @trace state 693 instr 474 op loop dests r612 r613 r614
// @trace state 694 instr 491 op sub dests r615
// @trace state 695 instr 492 op shra dests r616
// @trace state 696 instr 493 op convert dests r617
// @trace state 697 instr 494 op max dests r618
// @trace state 698 instr 495 op convert dests r619
// @trace state 699 instr 496 op min dests r620
// @trace state 700 instr 497 op sub dests r621
// @trace state 701 instr 498 op add dests r622
// @trace state 702 instr 499 op max dests r623
// @trace state 703 instr 500 op shra dests r624
// @trace state 705 instr 501 op concat dests r625
// @trace state 706 instr 502 op shl dests r626
// @trace state 707 instr 503 op mov dests r627
// @trace state 708 instr 504 op rev dests r628
// @trace state 709 instr 505 op reshape dests r629
// @trace state 710 instr 506 op iota dests r630
// @trace state 711 instr 507 op broadcast dests r631
// @trace state 712 instr 508 op iota dests r632
// @trace state 713 instr 509 op broadcast dests r633
// @trace state 714 instr 510 op add dests r634
// @trace state 715 instr 511 op lt dests r635
// @trace state 716 instr 512 op add dests r637
// @trace state 717 instr 513 op select_n dests r638
// @trace state 718 instr 514 op broadcast dests r639
// @trace state 719 instr 515 op gather dests r640
// @trace state 720 instr 516 op broadcast dests r641
// @trace state 721 instr 517 op add dests r642
// @trace state 722 instr 518 op convert dests r643
// @trace state 723 instr 519 op max dests r644
// @trace state 724 instr 520 op convert dests r645
// @trace state 725 instr 521 op min dests r646
// @trace state 726 instr 522 op sub dests r647
// @trace state 727 instr 523 op convert dests r648
// @trace state 728 instr 524 op max dests r649
// @trace state 729 instr 525 op convert dests r650
// @trace state 730 instr 526 op min dests r651
// @trace state 731 instr 527 op abs dests r652
// @trace state 733 instr 528 op reduce_max dests r653
// @trace state 734 instr 529 op sub dests r654
// @trace state 742 instr 531 op add dests r660
// @trace state 743 instr 532 op add dests r661
// @trace state 744 instr 533 op shra dests r662
// @trace state 745 instr 534 op broadcast dests r663
// @trace state 746 instr 535 op sub dests r664
// @trace state 747 instr 536 op max dests r665
// @trace state 749 instr 537 op reduce_sum dests r666
// @trace state 750 instr 538 op neg dests r667
// @trace state 751 instr 539 op broadcast dests r668
// @trace state 752 instr 540 op sub dests r669
// @trace state 753 instr 541 op max dests r670
// @trace state 755 instr 542 op reduce_sum dests r671
// @trace state 756 instr 543 op add dests r672
// @trace state 757 instr 544 op gt dests r673
// @trace state 758 instr 545 op select_n dests r674
// @trace state 759 instr 546 op select_n dests r675
// @trace state 766 instr 530 op loop dests r676 r677 r678
// @trace state 767 instr 547 op abs dests r679
// @trace state 769 instr 548 op reduce_max dests r680
// @trace state 770 instr 549 op sub dests r681
// @trace state 778 instr 551 op add dests r687
// @trace state 779 instr 552 op add dests r688
// @trace state 780 instr 553 op shra dests r689
// @trace state 781 instr 554 op broadcast dests r690
// @trace state 782 instr 555 op sub dests r691
// @trace state 783 instr 556 op max dests r692
// @trace state 785 instr 557 op reduce_sum dests r693
// @trace state 786 instr 558 op neg dests r694
// @trace state 787 instr 559 op broadcast dests r695
// @trace state 788 instr 560 op sub dests r696
// @trace state 789 instr 561 op max dests r697
// @trace state 791 instr 562 op reduce_sum dests r698
// @trace state 792 instr 563 op add dests r699
// @trace state 793 instr 564 op gt dests r700
// @trace state 794 instr 565 op select_n dests r701
// @trace state 795 instr 566 op select_n dests r702
// @trace state 802 instr 550 op loop dests r703 r704 r705
// @trace state 803 instr 567 op sub dests r706
// @trace state 804 instr 568 op transpose dests r707
// @trace state 805 instr 569 op broadcast dests r708
// @trace state 806 instr 570 op max dests r709
// @trace state 807 instr 571 op iota dests r710
// @trace state 808 instr 572 op broadcast dests r711
// @trace state 809 instr 573 op lt dests r712
// @trace state 810 instr 574 op convert dests r713
// @trace state 811 instr 575 op broadcast dests r714
// @trace state 812 instr 576 op select_n dests r715
// @trace state 814 instr 577 op reduce_sum dests r716
// @trace state 815 instr 578 op shl dests r718
// @trace state 816 instr 579 op lt dests r719
// @trace state 817 instr 580 op add dests r720
// @trace state 818 instr 581 op select_n dests r721
// @trace state 819 instr 582 op broadcast dests r722
// @trace state 820 instr 583 op gather dests r723
// @trace state 821 instr 584 op add dests r724
// @trace state 822 instr 585 op and dests r725
// @trace state 823 instr 586 op slice dests r726
// @trace state 824 instr 587 op shl dests r727
// @trace state 825 instr 588 op convert dests r728
// @trace state 827 instr 589 op pad dests r729
// @trace state 828 instr 590 op iota dests r730
// @trace state 829 instr 591 op shl dests r731
// @trace state 830 instr 592 op broadcast dests r732
// @trace state 831 instr 593 op iota dests r733
// @trace state 832 instr 594 op broadcast dests r734
// @trace state 833 instr 595 op add dests r735
// @trace state 834 instr 596 op broadcast dests r736
// @trace state 835 instr 597 op broadcast dests r737
// @trace state 836 instr 598 op add dests r738
// @trace state 837 instr 599 op lt dests r739
// @trace state 838 instr 600 op add dests r741
// @trace state 839 instr 601 op select_n dests r742
// @trace state 840 instr 602 op broadcast dests r743
// @trace state 841 instr 603 op gather dests r744
// @trace state 842 instr 604 op mov dests r745
// @trace state 843 instr 605 op broadcast dests r746
// @trace state 844 instr 606 op add dests r747
// @trace state 845 instr 607 op convert dests r748
// @trace state 846 instr 608 op max dests r749
// @trace state 847 instr 609 op convert dests r750
// @trace state 848 instr 610 op min dests r751
// @trace state 849 instr 611 op broadcast dests r752
// @trace state 850 instr 612 op sub dests r753
// @trace state 851 instr 613 op convert dests r754
// @trace state 852 instr 614 op max dests r755
// @trace state 853 instr 615 op convert dests r756
// @trace state 854 instr 616 op min dests r757
// @trace state 855 instr 617 op abs dests r758
// @trace state 857 instr 618 op reduce_max dests r759
// @trace state 858 instr 619 op sub dests r760
// @trace state 866 instr 621 op add dests r766
// @trace state 867 instr 622 op add dests r767
// @trace state 868 instr 623 op shra dests r768
// @trace state 869 instr 624 op broadcast dests r769
// @trace state 870 instr 625 op sub dests r770
// @trace state 871 instr 626 op max dests r771
// @trace state 873 instr 627 op reduce_sum dests r772
// @trace state 874 instr 628 op neg dests r773
// @trace state 875 instr 629 op broadcast dests r774
// @trace state 876 instr 630 op sub dests r775
// @trace state 877 instr 631 op max dests r776
// @trace state 879 instr 632 op reduce_sum dests r777
// @trace state 880 instr 633 op add dests r778
// @trace state 881 instr 634 op gt dests r779
// @trace state 882 instr 635 op select_n dests r780
// @trace state 883 instr 636 op select_n dests r781
// @trace state 890 instr 620 op loop dests r782 r783 r784
// @trace state 891 instr 637 op abs dests r785
// @trace state 893 instr 638 op reduce_max dests r786
// @trace state 894 instr 639 op sub dests r787
// @trace state 902 instr 641 op add dests r793
// @trace state 903 instr 642 op add dests r794
// @trace state 904 instr 643 op shra dests r795
// @trace state 905 instr 644 op broadcast dests r796
// @trace state 906 instr 645 op sub dests r797
// @trace state 907 instr 646 op max dests r798
// @trace state 909 instr 647 op reduce_sum dests r799
// @trace state 910 instr 648 op neg dests r800
// @trace state 911 instr 649 op broadcast dests r801
// @trace state 912 instr 650 op sub dests r802
// @trace state 913 instr 651 op max dests r803
// @trace state 915 instr 652 op reduce_sum dests r804
// @trace state 916 instr 653 op add dests r805
// @trace state 917 instr 654 op gt dests r806
// @trace state 918 instr 655 op select_n dests r807
// @trace state 919 instr 656 op select_n dests r808
// @trace state 926 instr 640 op loop dests r809 r810 r811
// @trace state 927 instr 657 op sub dests r812
// @trace state 928 instr 658 op shra dests r813
// @trace state 929 instr 659 op convert dests r814
// @trace state 930 instr 660 op max dests r815
// @trace state 931 instr 661 op convert dests r816
// @trace state 932 instr 662 op min dests r817
// @trace state 933 instr 663 op sub dests r818
// @trace state 934 instr 664 op add dests r819
// @trace state 935 instr 665 op max dests r820
// @trace state 936 instr 666 op shra dests r821
// @trace state 938 instr 667 op concat dests r822
// @trace state 939 instr 668 op shl dests r823
// @trace state 940 instr 669 op mov dests r824
// @trace state 941 instr 670 op rev dests r825
// @trace state 942 instr 671 op reshape dests r826
// @trace state 943 instr 672 op iota dests r827
// @trace state 944 instr 673 op broadcast dests r828
// @trace state 945 instr 674 op iota dests r829
// @trace state 946 instr 675 op broadcast dests r830
// @trace state 947 instr 676 op add dests r831
// @trace state 948 instr 677 op lt dests r832
// @trace state 949 instr 678 op add dests r834
// @trace state 950 instr 679 op select_n dests r835
// @trace state 951 instr 680 op broadcast dests r836
// @trace state 952 instr 681 op gather dests r837
// @trace state 953 instr 682 op broadcast dests r838
// @trace state 954 instr 683 op add dests r839
// @trace state 955 instr 684 op convert dests r840
// @trace state 956 instr 685 op max dests r841
// @trace state 957 instr 686 op convert dests r842
// @trace state 958 instr 687 op min dests r843
// @trace state 959 instr 688 op sub dests r844
// @trace state 960 instr 689 op convert dests r845
// @trace state 961 instr 690 op max dests r846
// @trace state 962 instr 691 op convert dests r847
// @trace state 963 instr 692 op min dests r848
// @trace state 964 instr 693 op abs dests r849
// @trace state 966 instr 694 op reduce_max dests r850
// @trace state 967 instr 695 op sub dests r851
// @trace state 975 instr 697 op add dests r857
// @trace state 976 instr 698 op add dests r858
// @trace state 977 instr 699 op shra dests r859
// @trace state 978 instr 700 op broadcast dests r860
// @trace state 979 instr 701 op sub dests r861
// @trace state 980 instr 702 op max dests r862
// @trace state 982 instr 703 op reduce_sum dests r863
// @trace state 983 instr 704 op neg dests r864
// @trace state 984 instr 705 op broadcast dests r865
// @trace state 985 instr 706 op sub dests r866
// @trace state 986 instr 707 op max dests r867
// @trace state 988 instr 708 op reduce_sum dests r868
// @trace state 989 instr 709 op add dests r869
// @trace state 990 instr 710 op gt dests r870
// @trace state 991 instr 711 op select_n dests r871
// @trace state 992 instr 712 op select_n dests r872
// @trace state 999 instr 696 op loop dests r873 r874 r875
// @trace state 1000 instr 713 op abs dests r876
// @trace state 1002 instr 714 op reduce_max dests r877
// @trace state 1003 instr 715 op sub dests r878
// @trace state 1011 instr 717 op add dests r884
// @trace state 1012 instr 718 op add dests r885
// @trace state 1013 instr 719 op shra dests r886
// @trace state 1014 instr 720 op broadcast dests r887
// @trace state 1015 instr 721 op sub dests r888
// @trace state 1016 instr 722 op max dests r889
// @trace state 1018 instr 723 op reduce_sum dests r890
// @trace state 1019 instr 724 op neg dests r891
// @trace state 1020 instr 725 op broadcast dests r892
// @trace state 1021 instr 726 op sub dests r893
// @trace state 1022 instr 727 op max dests r894
// @trace state 1024 instr 728 op reduce_sum dests r895
// @trace state 1025 instr 729 op add dests r896
// @trace state 1026 instr 730 op gt dests r897
// @trace state 1027 instr 731 op select_n dests r898
// @trace state 1028 instr 732 op select_n dests r899
// @trace state 1035 instr 716 op loop dests r900 r901 r902
// @trace state 1036 instr 733 op sub dests r903
// @trace state 1037 instr 734 op transpose dests r904
// @trace state 1038 instr 735 op broadcast dests r905
// @trace state 1039 instr 736 op max dests r906
// @trace state 1040 instr 737 op iota dests r907
// @trace state 1041 instr 738 op broadcast dests r908
// @trace state 1042 instr 739 op lt dests r909
// @trace state 1043 instr 740 op convert dests r910
// @trace state 1044 instr 741 op broadcast dests r911
// @trace state 1045 instr 742 op select_n dests r912
// @trace state 1047 instr 743 op reduce_sum dests r913
// @trace state 1048 instr 744 op shl dests r915
// @trace state 1049 instr 745 op lt dests r916
// @trace state 1050 instr 746 op add dests r917
// @trace state 1051 instr 747 op select_n dests r918
// @trace state 1052 instr 748 op broadcast dests r919
// @trace state 1053 instr 749 op gather dests r920
// @trace state 1054 instr 750 op add dests r921
// @trace state 1055 instr 751 op and dests r922
// @trace state 1056 instr 752 op slice dests r923
// @trace state 1057 instr 753 op shl dests r924
// @trace state 1058 instr 754 op convert dests r925
// @trace state 1060 instr 755 op pad dests r926
// @trace state 1061 instr 756 op iota dests r927
// @trace state 1062 instr 757 op shl dests r928
// @trace state 1063 instr 758 op broadcast dests r929
// @trace state 1064 instr 759 op iota dests r930
// @trace state 1065 instr 760 op broadcast dests r931
// @trace state 1066 instr 761 op add dests r932
// @trace state 1067 instr 762 op broadcast dests r933
// @trace state 1068 instr 763 op broadcast dests r934
// @trace state 1069 instr 764 op add dests r935
// @trace state 1070 instr 765 op lt dests r936
// @trace state 1071 instr 766 op add dests r938
// @trace state 1072 instr 767 op select_n dests r939
// @trace state 1073 instr 768 op broadcast dests r940
// @trace state 1074 instr 769 op gather dests r941
// @trace state 1075 instr 770 op mov dests r942
// @trace state 1076 instr 771 op broadcast dests r943
// @trace state 1077 instr 772 op add dests r944
// @trace state 1078 instr 773 op convert dests r945
// @trace state 1079 instr 774 op max dests r946
// @trace state 1080 instr 775 op convert dests r947
// @trace state 1081 instr 776 op min dests r948
// @trace state 1082 instr 777 op broadcast dests r949
// @trace state 1083 instr 778 op sub dests r950
// @trace state 1084 instr 779 op convert dests r951
// @trace state 1085 instr 780 op max dests r952
// @trace state 1086 instr 781 op convert dests r953
// @trace state 1087 instr 782 op min dests r954
// @trace state 1088 instr 783 op abs dests r955
// @trace state 1090 instr 784 op reduce_max dests r956
// @trace state 1091 instr 785 op sub dests r957
// @trace state 1099 instr 787 op add dests r963
// @trace state 1100 instr 788 op add dests r964
// @trace state 1101 instr 789 op shra dests r965
// @trace state 1102 instr 790 op broadcast dests r966
// @trace state 1103 instr 791 op sub dests r967
// @trace state 1104 instr 792 op max dests r968
// @trace state 1106 instr 793 op reduce_sum dests r969
// @trace state 1107 instr 794 op neg dests r970
// @trace state 1108 instr 795 op broadcast dests r971
// @trace state 1109 instr 796 op sub dests r972
// @trace state 1110 instr 797 op max dests r973
// @trace state 1112 instr 798 op reduce_sum dests r974
// @trace state 1113 instr 799 op add dests r975
// @trace state 1114 instr 800 op gt dests r976
// @trace state 1115 instr 801 op select_n dests r977
// @trace state 1116 instr 802 op select_n dests r978
// @trace state 1123 instr 786 op loop dests r979 r980 r981
// @trace state 1124 instr 803 op abs dests r982
// @trace state 1126 instr 804 op reduce_max dests r983
// @trace state 1127 instr 805 op sub dests r984
// @trace state 1135 instr 807 op add dests r990
// @trace state 1136 instr 808 op add dests r991
// @trace state 1137 instr 809 op shra dests r992
// @trace state 1138 instr 810 op broadcast dests r993
// @trace state 1139 instr 811 op sub dests r994
// @trace state 1140 instr 812 op max dests r995
// @trace state 1142 instr 813 op reduce_sum dests r996
// @trace state 1143 instr 814 op neg dests r997
// @trace state 1144 instr 815 op broadcast dests r998
// @trace state 1145 instr 816 op sub dests r999
// @trace state 1146 instr 817 op max dests r1000
// @trace state 1148 instr 818 op reduce_sum dests r1001
// @trace state 1149 instr 819 op add dests r1002
// @trace state 1150 instr 820 op gt dests r1003
// @trace state 1151 instr 821 op select_n dests r1004
// @trace state 1152 instr 822 op select_n dests r1005
// @trace state 1159 instr 806 op loop dests r1006 r1007 r1008
// @trace state 1160 instr 823 op sub dests r1009
// @trace state 1161 instr 824 op shra dests r1010
// @trace state 1162 instr 825 op convert dests r1011
// @trace state 1163 instr 826 op max dests r1012
// @trace state 1164 instr 827 op convert dests r1013
// @trace state 1165 instr 828 op min dests r1014
// @trace state 1166 instr 829 op sub dests r1015
// @trace state 1167 instr 830 op add dests r1016
// @trace state 1168 instr 831 op max dests r1017
// @trace state 1169 instr 832 op shra dests r1018
// @trace state 1171 instr 833 op concat dests r1019
// @trace state 1172 instr 834 op shl dests r1020
// @trace state 1173 instr 835 op mov dests r1021
// @trace state 1174 instr 836 op rev dests r1022
// @trace state 1175 instr 837 op reshape dests r1023
// @trace state 1176 instr 838 op iota dests r1024
// @trace state 1177 instr 839 op broadcast dests r1025
// @trace state 1178 instr 840 op iota dests r1026
// @trace state 1179 instr 841 op broadcast dests r1027
// @trace state 1180 instr 842 op add dests r1028
// @trace state 1181 instr 843 op lt dests r1029
// @trace state 1182 instr 844 op add dests r1031
// @trace state 1183 instr 845 op select_n dests r1032
// @trace state 1184 instr 846 op broadcast dests r1033
// @trace state 1185 instr 847 op gather dests r1034
// @trace state 1186 instr 848 op broadcast dests r1035
// @trace state 1187 instr 849 op add dests r1036
// @trace state 1188 instr 850 op convert dests r1037
// @trace state 1189 instr 851 op max dests r1038
// @trace state 1190 instr 852 op convert dests r1039
// @trace state 1191 instr 853 op min dests r1040
// @trace state 1192 instr 854 op sub dests r1041
// @trace state 1193 instr 855 op convert dests r1042
// @trace state 1194 instr 856 op max dests r1043
// @trace state 1195 instr 857 op convert dests r1044
// @trace state 1196 instr 858 op min dests r1045
// @trace state 1197 instr 859 op abs dests r1046
// @trace state 1199 instr 860 op reduce_max dests r1047
// @trace state 1200 instr 861 op sub dests r1048
// @trace state 1208 instr 863 op add dests r1054
// @trace state 1209 instr 864 op add dests r1055
// @trace state 1210 instr 865 op shra dests r1056
// @trace state 1211 instr 866 op broadcast dests r1057
// @trace state 1212 instr 867 op sub dests r1058
// @trace state 1213 instr 868 op max dests r1059
// @trace state 1215 instr 869 op reduce_sum dests r1060
// @trace state 1216 instr 870 op neg dests r1061
// @trace state 1217 instr 871 op broadcast dests r1062
// @trace state 1218 instr 872 op sub dests r1063
// @trace state 1219 instr 873 op max dests r1064
// @trace state 1221 instr 874 op reduce_sum dests r1065
// @trace state 1222 instr 875 op add dests r1066
// @trace state 1223 instr 876 op gt dests r1067
// @trace state 1224 instr 877 op select_n dests r1068
// @trace state 1225 instr 878 op select_n dests r1069
// @trace state 1232 instr 862 op loop dests r1070 r1071 r1072
// @trace state 1233 instr 879 op abs dests r1073
// @trace state 1235 instr 880 op reduce_max dests r1074
// @trace state 1236 instr 881 op sub dests r1075
// @trace state 1244 instr 883 op add dests r1081
// @trace state 1245 instr 884 op add dests r1082
// @trace state 1246 instr 885 op shra dests r1083
// @trace state 1247 instr 886 op broadcast dests r1084
// @trace state 1248 instr 887 op sub dests r1085
// @trace state 1249 instr 888 op max dests r1086
// @trace state 1251 instr 889 op reduce_sum dests r1087
// @trace state 1252 instr 890 op neg dests r1088
// @trace state 1253 instr 891 op broadcast dests r1089
// @trace state 1254 instr 892 op sub dests r1090
// @trace state 1255 instr 893 op max dests r1091
// @trace state 1257 instr 894 op reduce_sum dests r1092
// @trace state 1258 instr 895 op add dests r1093
// @trace state 1259 instr 896 op gt dests r1094
// @trace state 1260 instr 897 op select_n dests r1095
// @trace state 1261 instr 898 op select_n dests r1096
// @trace state 1268 instr 882 op loop dests r1097 r1098 r1099
// @trace state 1269 instr 899 op sub dests r1100
// @trace state 1270 instr 900 op transpose dests r1101
// @trace state 1271 instr 901 op broadcast dests r1102
// @trace state 1272 instr 902 op max dests r1103
// @trace state 1273 instr 903 op iota dests r1104
// @trace state 1274 instr 904 op broadcast dests r1105
// @trace state 1275 instr 905 op lt dests r1106
// @trace state 1276 instr 906 op convert dests r1107
// @trace state 1277 instr 907 op broadcast dests r1108
// @trace state 1278 instr 908 op select_n dests r1109
// @trace state 1280 instr 909 op reduce_sum dests r1110
// @trace state 1281 instr 910 op shl dests r1112
// @trace state 1282 instr 911 op lt dests r1113
// @trace state 1283 instr 912 op add dests r1114
// @trace state 1284 instr 913 op select_n dests r1115
// @trace state 1285 instr 914 op broadcast dests r1116
// @trace state 1286 instr 915 op gather dests r1117
// @trace state 1287 instr 916 op add dests r1118
// @trace state 1293 instr 917 op concat dests r1119
// @trace state 1294 instr 918 op add dests r1120
// @trace state 1295 instr 919 op add dests r1121
// @trace state 1296 instr 920 op mov dests r1122
// @trace state 1297 instr 921 op broadcast dests r1123
// @trace state 1298 instr 922 op sub dests r1124
// @trace state 1299 instr 923 op mov dests r1125
// @trace state 1300 instr 924 op ge dests r1126
// @trace state 1301 instr 925 op max dests r1127
// @trace state 1302 instr 926 op broadcast dests r1128
// @trace state 1303 instr 927 op shl dests r1129
// @trace state 1304 instr 928 op neg dests r1130
// @trace state 1305 instr 929 op max dests r1131
// @trace state 1306 instr 930 op broadcast dests r1132
// @trace state 1307 instr 931 op shra dests r1133
// @trace state 1308 instr 932 op broadcast dests r1134
// @trace state 1309 instr 933 op select_n dests r1135
// @trace state 1310 instr 934 op mov dests r1136
// @trace state 1311 instr 935 op ge dests r1137
// @trace state 1312 instr 936 op max dests r1138
// @trace state 1313 instr 937 op broadcast dests r1139
// @trace state 1314 instr 938 op shl dests r1140
// @trace state 1315 instr 939 op neg dests r1141
// @trace state 1316 instr 940 op max dests r1142
// @trace state 1317 instr 941 op broadcast dests r1143
// @trace state 1318 instr 942 op shra dests r1144
// @trace state 1319 instr 943 op broadcast dests r1145
// @trace state 1320 instr 944 op select_n dests r1146
// @trace state 1321 instr 945 op mov dests r1147
// @trace state 1322 instr 946 op gt dests r1148
// @trace state 1323 instr 947 op add dests r1149
// @trace state 1324 instr 948 op lt dests r1150
// @trace state 1325 instr 949 op sub dests r1151
// @trace state 1326 instr 950 op broadcast dests r1152
// @trace state 1327 instr 951 op select_n dests r1153
// @trace state 1328 instr 952 op broadcast dests r1154
// @trace state 1329 instr 953 op select_n dests r1155
// @trace state 1330 instr 954 op convert dests r1156
// @trace state 1331 instr 955 op max dests r1157
// @trace state 1332 instr 956 op convert dests r1158
// @trace state 1333 instr 957 op min dests r1159
// @trace state 1334 instr 958 op shl dests r1160
// @trace state 1335 instr 959 op broadcast dests r1161
// @trace state 1336 instr 960 op broadcast dests r1162
// @trace state 1337 instr 961 op neg dests r1163
// @trace state 1338 instr 962 op mov dests r1164
// @trace state 1339 instr 963 op mov dests r1165
// @trace state 1340 instr 964 op broadcast dests r1166
// @trace state 1341 instr 965 op add dests r1167
// @trace state 1342 instr 966 op convert dests r1168
// @trace state 1343 instr 967 op max dests r1169
// @trace state 1344 instr 968 op convert dests r1170
// @trace state 1345 instr 969 op min dests r1171
// @trace state 1346 instr 970 op broadcast dests r1172
// @trace state 1347 instr 971 op add dests r1173
// @trace state 1348 instr 972 op convert dests r1174
// @trace state 1349 instr 973 op max dests r1175
// @trace state 1350 instr 974 op convert dests r1176
// @trace state 1351 instr 975 op min dests r1177
// @trace state 1353 instr 976 op concat dests r1178
// @trace state 1354 instr 977 op mov dests r1179
// @trace state 1355 instr 978 op broadcast dests r1180
// @trace state 1357 instr 979 op concat dests r1181
// @trace state 1358 instr 980 op transpose dests r1182
// @trace state 1360 instr 981 op reduce_max dests r1183
// @trace state 1361 instr 982 op sub dests r1185
// @trace state 1369 instr 984 op add dests r1191
// @trace state 1370 instr 985 op add dests r1192
// @trace state 1371 instr 986 op shra dests r1193
// @trace state 1372 instr 987 op broadcast dests r1194
// @trace state 1373 instr 988 op sub dests r1195
// @trace state 1374 instr 989 op max dests r1196
// @trace state 1376 instr 990 op reduce_sum dests r1197
// @trace state 1377 instr 991 op gt dests r1198
// @trace state 1378 instr 992 op select_n dests r1199
// @trace state 1379 instr 993 op select_n dests r1200
// @trace state 1386 instr 983 op loop dests r1201 r1202 r1203
// @trace state 1387 instr 994 op broadcast dests r1204
// @trace state 1388 instr 995 op add dests r1205
// @trace state 1389 instr 996 op convert dests r1206
// @trace state 1390 instr 997 op max dests r1207
// @trace state 1391 instr 998 op convert dests r1208
// @trace state 1392 instr 999 op min dests r1209
// @trace state 1393 instr 1000 op broadcast dests r1210
// @trace state 1394 instr 1001 op add dests r1211
// @trace state 1395 instr 1002 op convert dests r1212
// @trace state 1396 instr 1003 op max dests r1213
// @trace state 1397 instr 1004 op convert dests r1214
// @trace state 1398 instr 1005 op min dests r1215
// @trace state 1400 instr 1006 op concat dests r1216
// @trace state 1401 instr 1007 op mov dests r1217
// @trace state 1402 instr 1008 op broadcast dests r1218
// @trace state 1404 instr 1009 op concat dests r1219
// @trace state 1405 instr 1010 op transpose dests r1220
// @trace state 1407 instr 1011 op reduce_max dests r1221
// @trace state 1408 instr 1012 op sub dests r1222
// @trace state 1416 instr 1014 op add dests r1228
// @trace state 1417 instr 1015 op add dests r1229
// @trace state 1418 instr 1016 op shra dests r1230
// @trace state 1419 instr 1017 op broadcast dests r1231
// @trace state 1420 instr 1018 op sub dests r1232
// @trace state 1421 instr 1019 op max dests r1233
// @trace state 1423 instr 1020 op reduce_sum dests r1234
// @trace state 1424 instr 1021 op gt dests r1235
// @trace state 1425 instr 1022 op select_n dests r1236
// @trace state 1426 instr 1023 op select_n dests r1237
// @trace state 1433 instr 1013 op loop dests r1238 r1239 r1240
// @trace state 1434 instr 1024 op broadcast dests r1241
// @trace state 1435 instr 1025 op broadcast dests r1242
// @trace state 1437 instr 1026 op concat dests r1243
// @trace state 1439 instr 1027 op reduce_max dests r1244
// @trace state 1440 instr 1028 op sub dests r1246
// @trace state 1448 instr 1030 op add dests r1252
// @trace state 1449 instr 1031 op add dests r1253
// @trace state 1450 instr 1032 op shra dests r1254
// @trace state 1451 instr 1033 op broadcast dests r1255
// @trace state 1452 instr 1034 op sub dests r1256
// @trace state 1453 instr 1035 op max dests r1257
// @trace state 1455 instr 1036 op reduce_sum dests r1258
// @trace state 1456 instr 1037 op gt dests r1259
// @trace state 1457 instr 1038 op select_n dests r1260
// @trace state 1458 instr 1039 op select_n dests r1261
// @trace state 1465 instr 1029 op loop dests r1262 r1263 r1264
// @trace state 1466 instr 1040 op sub dests r1265
// @trace state 1467 instr 1041 op max dests r1266
// @trace state 1468 instr 1042 op sub dests r1267
// @trace state 1469 instr 1043 op max dests r1268
// @trace state 1470 instr 1044 op sub dests r1269

module session_step_q(input wire clk, input wire rst, input wire start, output reg done);
  reg signed [7:0] r0 [0:14];
  reg signed [7:0] r1 [0:14];
  reg signed [7:0] r2 [0:14];
  reg signed [7:0] r3 [0:14];
  reg signed [7:0] r4 [0:14];
  reg signed [7:0] r5 [0:14];
  reg signed [31:0] r6 [0:0];
  reg signed [31:0] r7 [0:0];
  reg signed [31:0] r8 [0:0];
  reg signed [31:0] r9 [0:0];
  reg signed [31:0] r10 [0:0];
  reg signed [31:0] r11 [0:0];
  reg signed [23:0] r12 [0:29];
  reg signed [8:0] r13 [0:0];
  reg signed [31:0] r14 [0:0];
  reg r15 [0:0];
  reg signed [7:0] r16 [0:159];
  reg signed [8:0] r17 [0:0];
  reg signed [8:0] r26 [0:159];
  reg signed [8:0] r27 [0:0];
  reg signed [8:0] r28 [0:0];
  reg signed [7:0] r29 [0:174];
  reg signed [8:0] r31 [0:174];
  reg signed [5:0] r32 [0:79];
  reg signed [5:0] r33 [0:79];
  reg signed [5:0] r34 [0:79];
  reg signed [8:0] r35 [0:159];
  reg signed [8:0] r36 [0:159];
  reg signed [4:0] r37 [0:15];
  reg signed [4:0] r38 [0:15];
  reg signed [8:0] r39 [0:2559];
  reg r41 [0:2559];
  reg signed [9:0] r43 [0:2559];
  reg signed [8:0] r44 [0:2559];
  reg signed [8:0] r45 [0:2559];
  reg signed [8:0] r46 [0:2559];
  reg signed [8:0] r47 [0:2559];
  reg signed [9:0] r48 [0:12799];
  reg signed [9:0] r51 [0:0];
  reg signed [9:0] r52 [0:12799];
  reg signed [9:0] r53 [0:0];
  reg signed [9:0] r54 [0:12799];
  reg signed [9:0] r55 [0:12799];
  reg signed [9:0] r56 [0:0];
  reg signed [9:0] r57 [0:12799];
  reg signed [9:0] r58 [0:0];
  reg signed [9:0] r59 [0:12799];
  reg signed [9:0] r60 [0:12799];
  reg signed [9:0] r61 [0:799];
  reg signed [9:0] r63 [0:799];
  reg signed [31:0] r64 [0:12799];
  reg signed [31:0] r65 [0:0];
  reg signed [31:0] r66 [0:0];
  reg signed [31:0] r67 [0:799];
  reg signed [31:0] r68 [0:799];
  reg signed [4:0] r69 [0:0];
  reg signed [10:0] r70 [0:799];
  reg signed [9:0] r71 [0:799];
  reg signed [9:0] r72 [0:799];
  reg signed [10:0] r73 [0:12799];
  reg signed [10:0] r74 [0:12799];
  reg signed [14:0] r75 [0:799];
  reg signed [9:0] r76 [0:12799];
  reg signed [9:0] r77 [0:799];
  reg signed [10:0] r78 [0:12799];
  reg signed [10:0] r79 [0:12799];
  reg signed [14:0] r80 [0:799];
  reg signed [15:0] r81 [0:799];
  reg r82 [0:799];
  reg signed [9:0] r83 [0:799];
  reg signed [9:0] r84 [0:799];
  reg signed [9:0] r85 [0:0];
  reg signed [9:0] r86 [0:799];
  reg signed [9:0] r87 [0:799];
  reg signed [9:0] r88 [0:12799];
  reg signed [9:0] r89 [0:799];
  reg signed [9:0] r90 [0:799];
  reg signed [31:0] r91 [0:12799];
  reg signed [31:0] r92 [0:0];
  reg signed [31:0] r93 [0:0];
  reg signed [31:0] r94 [0:799];
  reg signed [31:0] r95 [0:799];
  reg signed [4:0] r96 [0:0];
  reg signed [10:0] r97 [0:799];
  reg signed [9:0] r98 [0:799];
  reg signed [9:0] r99 [0:799];
  reg signed [10:0] r100 [0:12799];
  reg signed [10:0] r101 [0:12799];
  reg signed [14:0] r102 [0:799];
  reg signed [9:0] r103 [0:12799];
  reg signed [9:0] r104 [0:799];
  reg signed [10:0] r105 [0:12799];
  reg signed [10:0] r106 [0:12799];
  reg signed [14:0] r107 [0:799];
  reg signed [15:0] r108 [0:799];
  reg r109 [0:799];
  reg signed [9:0] r110 [0:799];
  reg signed [9:0] r111 [0:799];
  reg signed [9:0] r112 [0:0];
  reg signed [9:0] r113 [0:799];
  reg signed [9:0] r114 [0:799];
  reg signed [10:0] r115 [0:799];
  reg signed [10:0] r116 [0:799];
  reg signed [8:0] r117 [0:0];
  reg signed [10:0] r118 [0:799];
  reg signed [8:0] r119 [0:799];
  reg signed [8:0] r120 [0:0];
  reg r121 [0:799];
  reg signed [0:0] r122 [0:0];
  reg signed [0:0] r123 [0:799];
  reg signed [10:0] r124 [0:799];
  reg signed [17:0] r125 [0:4];
  reg signed [17:0] r126 [0:4];
  reg r127 [0:0];
  reg signed [9:0] r128 [0:0];
  reg signed [8:0] r129 [0:0];
  reg signed [8:0] r130 [0:0];
  reg signed [7:0] r131 [0:14];
  reg signed [31:0] r132 [0:0];
  reg signed [1:0] r133 [0:0];
  reg signed [7:0] r134 [0:164];
  reg signed [8:0] r135 [0:164];
  reg signed [0:0] r136 [0:0];
  reg signed [8:0] r137 [0:165];
  reg signed [7:0] r138 [0:79];
  reg signed [8:0] r139 [0:79];
  reg signed [8:0] r140 [0:79];
  reg signed [3:0] r141 [0:5];
  reg signed [3:0] r142 [0:5];
  reg signed [8:0] r143 [0:479];
  reg signed [8:0] r144 [0:479];
  reg signed [1:0] r145 [0:0];
  reg signed [8:0] r146 [0:479];
  reg r147 [0:479];
  reg signed [9:0] r149 [0:479];
  reg signed [8:0] r150 [0:479];
  reg signed [8:0] r151 [0:479];
  reg signed [8:0] r152 [0:479];
  reg signed [6:0] r153 [0:5];
  reg signed [6:0] r154 [0:5];
  reg signed [9:0] r155 [0:479];
  reg signed [9:0] r156 [0:0];
  reg signed [9:0] r157 [0:479];
  reg signed [9:0] r158 [0:0];
  reg signed [9:0] r159 [0:479];
  reg signed [6:0] r160 [0:5];
  reg signed [9:0] r161 [0:479];
  reg signed [9:0] r162 [0:0];
  reg signed [9:0] r163 [0:479];
  reg signed [9:0] r164 [0:0];
  reg signed [9:0] r165 [0:479];
  reg signed [9:0] r166 [0:479];
  reg signed [9:0] r167 [0:79];
  reg signed [9:0] r168 [0:79];
  reg signed [31:0] r169 [0:479];
  reg signed [31:0] r170 [0:0];
  reg signed [31:0] r171 [0:0];
  reg signed [31:0] r172 [0:79];
  reg signed [31:0] r173 [0:79];
  reg signed [4:0] r174 [0:0];
  reg signed [10:0] r175 [0:79];
  reg signed [9:0] r176 [0:79];
  reg signed [9:0] r177 [0:79];
  reg signed [10:0] r178 [0:479];
  reg signed [10:0] r179 [0:479];
  reg signed [13:0] r180 [0:79];
  reg signed [9:0] r181 [0:479];
  reg signed [9:0] r182 [0:79];
  reg signed [10:0] r183 [0:479];
  reg signed [10:0] r184 [0:479];
  reg signed [13:0] r185 [0:79];
  reg signed [14:0] r186 [0:79];
  reg r187 [0:79];
  reg signed [9:0] r188 [0:79];
  reg signed [9:0] r189 [0:79];
  reg signed [9:0] r190 [0:0];
  reg signed [9:0] r191 [0:79];
  reg signed [9:0] r192 [0:79];
  reg signed [9:0] r193 [0:479];
  reg signed [9:0] r194 [0:79];
  reg signed [9:0] r195 [0:79];
  reg signed [31:0] r196 [0:479];
  reg signed [31:0] r197 [0:0];
  reg signed [31:0] r198 [0:0];
  reg signed [31:0] r199 [0:79];
  reg signed [31:0] r200 [0:79];
  reg signed [4:0] r201 [0:0];
  reg signed [10:0] r202 [0:79];
  reg signed [9:0] r203 [0:79];
  reg signed [9:0] r204 [0:79];
  reg signed [10:0] r205 [0:479];
  reg signed [10:0] r206 [0:479];
  reg signed [13:0] r207 [0:79];
  reg signed [9:0] r208 [0:479];
  reg signed [9:0] r209 [0:79];
  reg signed [10:0] r210 [0:479];
  reg signed [10:0] r211 [0:479];
  reg signed [13:0] r212 [0:79];
  reg signed [14:0] r213 [0:79];
  reg r214 [0:79];
  reg signed [9:0] r215 [0:79];
  reg signed [9:0] r216 [0:79];
  reg signed [9:0] r217 [0:0];
  reg signed [9:0] r218 [0:79];
  reg signed [9:0] r219 [0:79];
  reg signed [10:0] r220 [0:79];
  reg signed [9:0] r221 [0:79];
  reg signed [7:0] r224 [0:0];
  reg signed [9:0] r225 [0:79];
  reg signed [7:0] r226 [0:0];
  reg signed [7:0] r227 [0:79];
  reg signed [8:0] r228 [0:0];
  reg signed [8:0] r229 [0:0];
  reg signed [8:0] r230 [0:0];
  reg signed [7:0] r231 [0:0];
  reg signed [7:0] r232 [0:94];
  reg signed [8:0] r233 [0:94];
  reg signed [5:0] r234 [0:79];
  reg signed [5:0] r235 [0:79];
  reg signed [5:0] r236 [0:79];
  reg signed [7:0] r237 [0:79];
  reg signed [7:0] r238 [0:79];
  reg signed [4:0] r239 [0:15];
  reg signed [4:0] r240 [0:15];
  reg signed [7:0] r241 [0:1279];
  reg r242 [0:1279];
  reg signed [8:0] r244 [0:1279];
  reg signed [7:0] r245 [0:1279];
  reg signed [7:0] r246 [0:1279];
  reg signed [8:0] r247 [0:1279];
  reg signed [8:0] r248 [0:1279];
  reg signed [9:0] r249 [0:6399];
  reg signed [9:0] r250 [0:0];
  reg signed [9:0] r251 [0:6399];
  reg signed [9:0] r252 [0:0];
  reg signed [9:0] r253 [0:6399];
  reg signed [9:0] r254 [0:6399];
  reg signed [9:0] r255 [0:0];
  reg signed [9:0] r256 [0:6399];
  reg signed [9:0] r257 [0:0];
  reg signed [9:0] r258 [0:6399];
  reg signed [9:0] r259 [0:6399];
  reg signed [9:0] r260 [0:399];
  reg signed [9:0] r261 [0:399];
  reg signed [31:0] r262 [0:6399];
  reg signed [31:0] r263 [0:0];
  reg signed [31:0] r264 [0:0];
  reg signed [31:0] r265 [0:399];
  reg signed [31:0] r266 [0:399];
  reg signed [4:0] r267 [0:0];
  reg signed [10:0] r268 [0:399];
  reg signed [9:0] r269 [0:399];
  reg signed [9:0] r270 [0:399];
  reg signed [10:0] r271 [0:6399];
  reg signed [10:0] r272 [0:6399];
  reg signed [14:0] r273 [0:399];
  reg signed [9:0] r274 [0:6399];
  reg signed [9:0] r275 [0:399];
  reg signed [10:0] r276 [0:6399];
  reg signed [10:0] r277 [0:6399];
  reg signed [14:0] r278 [0:399];
  reg signed [15:0] r279 [0:399];
  reg r280 [0:399];
  reg signed [9:0] r281 [0:399];
  reg signed [9:0] r282 [0:399];
  reg signed [9:0] r283 [0:0];
  reg signed [9:0] r284 [0:399];
  reg signed [9:0] r285 [0:399];
  reg signed [9:0] r286 [0:6399];
  reg signed [9:0] r287 [0:399];
  reg signed [9:0] r288 [0:399];
  reg signed [31:0] r289 [0:6399];
  reg signed [31:0] r290 [0:0];
  reg signed [31:0] r291 [0:0];
  reg signed [31:0] r292 [0:399];
  reg signed [31:0] r293 [0:399];
  reg signed [4:0] r294 [0:0];
  reg signed [10:0] r295 [0:399];
  reg signed [9:0] r296 [0:399];
  reg signed [9:0] r297 [0:399];
  reg signed [10:0] r298 [0:6399];
  reg signed [10:0] r299 [0:6399];
  reg signed [14:0] r300 [0:399];
  reg signed [9:0] r301 [0:6399];
  reg signed [9:0] r302 [0:399];
  reg signed [10:0] r303 [0:6399];
  reg signed [10:0] r304 [0:6399];
  reg signed [14:0] r305 [0:399];
  reg signed [15:0] r306 [0:399];
  reg r307 [0:399];
  reg signed [9:0] r308 [0:399];
  reg signed [9:0] r309 [0:399];
  reg signed [9:0] r310 [0:0];
  reg signed [9:0] r311 [0:399];
  reg signed [9:0] r312 [0:399];
  reg signed [10:0] r313 [0:399];
  reg signed [10:0] r314 [0:399];
  reg signed [7:0] r315 [0:0];
  reg signed [10:0] r316 [0:399];
  reg signed [7:0] r317 [0:399];
  reg signed [7:0] r318 [0:0];
  reg r319 [0:399];
  reg signed [0:0] r320 [0:0];
  reg signed [0:0] r321 [0:399];
  reg signed [10:0] r322 [0:399];
  reg signed [16:0] r323 [0:4];
  reg signed [17:0] r324 [0:4];
  reg r325 [0:0];
  reg signed [8:0] r326 [0:0];
  reg signed [7:0] r327 [0:0];
  reg signed [7:0] r328 [0:0];
  reg signed [7:0] r329 [0:14];
  reg signed [31:0] r330 [0:0];
  reg signed [1:0] r331 [0:0];
  reg signed [7:0] r332 [0:84];
  reg signed [8:0] r333 [0:84];
  reg signed [0:0] r334 [0:0];
  reg signed [8:0] r335 [0:85];
  reg signed [6:0] r336 [0:39];
  reg signed [7:0] r337 [0:39];
  reg signed [7:0] r338 [0:39];
  reg signed [3:0] r339 [0:5];
  reg signed [3:0] r340 [0:5];
  reg signed [7:0] r341 [0:239];
  reg signed [7:0] r342 [0:239];
  reg signed [1:0] r343 [0:0];
  reg signed [7:0] r344 [0:239];
  reg r345 [0:239];
  reg signed [8:0] r347 [0:239];
  reg signed [7:0] r348 [0:239];
  reg signed [7:0] r349 [0:239];
  reg signed [8:0] r350 [0:239];
  reg signed [6:0] r351 [0:5];
  reg signed [6:0] r352 [0:5];
  reg signed [9:0] r353 [0:239];
  reg signed [9:0] r354 [0:0];
  reg signed [9:0] r355 [0:239];
  reg signed [9:0] r356 [0:0];
  reg signed [9:0] r357 [0:239];
  reg signed [6:0] r358 [0:5];
  reg signed [9:0] r359 [0:239];
  reg signed [9:0] r360 [0:0];
  reg signed [9:0] r361 [0:239];
  reg signed [9:0] r362 [0:0];
  reg signed [9:0] r363 [0:239];
  reg signed [9:0] r364 [0:239];
  reg signed [9:0] r365 [0:39];
  reg signed [9:0] r366 [0:39];
  reg signed [31:0] r367 [0:239];
  reg signed [31:0] r368 [0:0];
  reg signed [31:0] r369 [0:0];
  reg signed [31:0] r370 [0:39];
  reg signed [31:0] r371 [0:39];
  reg signed [4:0] r372 [0:0];
  reg signed [10:0] r373 [0:39];
  reg signed [9:0] r374 [0:39];
  reg signed [9:0] r375 [0:39];
  reg signed [10:0] r376 [0:239];
  reg signed [10:0] r377 [0:239];
  reg signed [13:0] r378 [0:39];
  reg signed [9:0] r379 [0:239];
  reg signed [9:0] r380 [0:39];
  reg signed [10:0] r381 [0:239];
  reg signed [10:0] r382 [0:239];
  reg signed [13:0] r383 [0:39];
  reg signed [14:0] r384 [0:39];
  reg r385 [0:39];
  reg signed [9:0] r386 [0:39];
  reg signed [9:0] r387 [0:39];
  reg signed [9:0] r388 [0:0];
  reg signed [9:0] r389 [0:39];
  reg signed [9:0] r390 [0:39];
  reg signed [9:0] r391 [0:239];
  reg signed [9:0] r392 [0:39];
  reg signed [9:0] r393 [0:39];
  reg signed [31:0] r394 [0:239];
  reg signed [31:0] r395 [0:0];
  reg signed [31:0] r396 [0:0];
  reg signed [31:0] r397 [0:39];
  reg signed [31:0] r398 [0:39];
  reg signed [4:0] r399 [0:0];
  reg signed [10:0] r400 [0:39];
  reg signed [9:0] r401 [0:39];
  reg signed [9:0] r402 [0:39];
  reg signed [10:0] r403 [0:239];
  reg signed [10:0] r404 [0:239];
  reg signed [13:0] r405 [0:39];
  reg signed [9:0] r406 [0:239];
  reg signed [9:0] r407 [0:39];
  reg signed [10:0] r408 [0:239];
  reg signed [10:0] r409 [0:239];
  reg signed [13:0] r410 [0:39];
  reg signed [14:0] r411 [0:39];
  reg r412 [0:39];
  reg signed [9:0] r413 [0:39];
  reg signed [9:0] r414 [0:39];
  reg signed [9:0] r415 [0:0];
  reg signed [9:0] r416 [0:39];
  reg signed [9:0] r417 [0:39];
  reg signed [10:0] r418 [0:39];
  reg signed [9:0] r419 [0:39];
  reg signed [7:0] r420 [0:0];
  reg signed [9:0] r421 [0:39];
  reg signed [7:0] r422 [0:0];
  reg signed [7:0] r423 [0:39];
  reg signed [7:0] r424 [0:0];
  reg signed [7:0] r425 [0:0];
  reg signed [7:0] r426 [0:0];
  reg signed [6:0] r427 [0:0];
  reg signed [7:0] r428 [0:54];
  reg signed [8:0] r429 [0:54];
  reg signed [5:0] r430 [0:79];
  reg signed [5:0] r431 [0:79];
  reg signed [5:0] r432 [0:79];
  reg signed [6:0] r433 [0:39];
  reg signed [6:0] r434 [0:39];
  reg signed [4:0] r435 [0:15];
  reg signed [4:0] r436 [0:15];
  reg signed [6:0] r437 [0:639];
  reg r438 [0:639];
  reg signed [7:0] r440 [0:639];
  reg signed [6:0] r441 [0:639];
  reg signed [6:0] r442 [0:639];
  reg signed [8:0] r443 [0:639];
  reg signed [8:0] r444 [0:639];
  reg signed [9:0] r445 [0:3199];
  reg signed [9:0] r446 [0:0];
  reg signed [9:0] r447 [0:3199];
  reg signed [9:0] r448 [0:0];
  reg signed [9:0] r449 [0:3199];
  reg signed [9:0] r450 [0:3199];
  reg signed [9:0] r451 [0:0];
  reg signed [9:0] r452 [0:3199];
  reg signed [9:0] r453 [0:0];
  reg signed [9:0] r454 [0:3199];
  reg signed [9:0] r455 [0:3199];
  reg signed [9:0] r456 [0:199];
  reg signed [9:0] r457 [0:199];
  reg signed [31:0] r458 [0:3199];
  reg signed [31:0] r459 [0:0];
  reg signed [31:0] r460 [0:0];
  reg signed [31:0] r461 [0:199];
  reg signed [31:0] r462 [0:199];
  reg signed [4:0] r463 [0:0];
  reg signed [10:0] r464 [0:199];
  reg signed [9:0] r465 [0:199];
  reg signed [9:0] r466 [0:199];
  reg signed [10:0] r467 [0:3199];
  reg signed [10:0] r468 [0:3199];
  reg signed [14:0] r469 [0:199];
  reg signed [9:0] r470 [0:3199];
  reg signed [9:0] r471 [0:199];
  reg signed [10:0] r472 [0:3199];
  reg signed [10:0] r473 [0:3199];
  reg signed [14:0] r474 [0:199];
  reg signed [15:0] r475 [0:199];
  reg r476 [0:199];
  reg signed [9:0] r477 [0:199];
  reg signed [9:0] r478 [0:199];
  reg signed [9:0] r479 [0:0];
  reg signed [9:0] r480 [0:199];
  reg signed [9:0] r481 [0:199];
  reg signed [9:0] r482 [0:3199];
  reg signed [9:0] r483 [0:199];
  reg signed [9:0] r484 [0:199];
  reg signed [31:0] r485 [0:3199];
  reg signed [31:0] r486 [0:0];
  reg signed [31:0] r487 [0:0];
  reg signed [31:0] r488 [0:199];
  reg signed [31:0] r489 [0:199];
  reg signed [4:0] r490 [0:0];
  reg signed [10:0] r491 [0:199];
  reg signed [9:0] r492 [0:199];
  reg signed [9:0] r493 [0:199];
  reg signed [10:0] r494 [0:3199];
  reg signed [10:0] r495 [0:3199];
  reg signed [14:0] r496 [0:199];
  reg signed [9:0] r497 [0:3199];
  reg signed [9:0] r498 [0:199];
  reg signed [10:0] r499 [0:3199];
  reg signed [10:0] r500 [0:3199];
  reg signed [14:0] r501 [0:199];
  reg signed [15:0] r502 [0:199];
  reg r503 [0:199];
  reg signed [9:0] r504 [0:199];
  reg signed [9:0] r505 [0:199];
  reg signed [9:0] r506 [0:0];
  reg signed [9:0] r507 [0:199];
  reg signed [9:0] r508 [0:199];
  reg signed [10:0] r509 [0:199];
  reg signed [10:0] r510 [0:199];
  reg signed [6:0] r511 [0:0];
  reg signed [10:0] r512 [0:199];
  reg signed [6:0] r513 [0:199];
  reg signed [6:0] r514 [0:0];
  reg r515 [0:199];
  reg signed [0:0] r516 [0:0];
  reg signed [0:0] r517 [0:199];
  reg signed [10:0] r518 [0:199];
  reg signed [15:0] r519 [0:4];
  reg signed [17:0] r521 [0:4];
  reg r522 [0:0];
  reg signed [7:0] r523 [0:0];
  reg signed [6:0] r524 [0:0];
  reg signed [6:0] r525 [0:0];
  reg signed [7:0] r526 [0:14];
  reg signed [31:0] r527 [0:0];
  reg signed [1:0] r528 [0:0];
  reg signed [7:0] r529 [0:44];
  reg signed [8:0] r530 [0:44];
  reg signed [0:0] r531 [0:0];
  reg signed [8:0] r532 [0:45];
  reg signed [5:0] r533 [0:19];
  reg signed [6:0] r534 [0:19];
  reg signed [6:0] r535 [0:19];
  reg signed [3:0] r536 [0:5];
  reg signed [3:0] r537 [0:5];
  reg signed [6:0] r538 [0:119];
  reg signed [6:0] r539 [0:119];
  reg signed [1:0] r540 [0:0];
  reg signed [6:0] r541 [0:119];
  reg r542 [0:119];
  reg signed [7:0] r544 [0:119];
  reg signed [6:0] r545 [0:119];
  reg signed [6:0] r546 [0:119];
  reg signed [8:0] r547 [0:119];
  reg signed [6:0] r548 [0:5];
  reg signed [6:0] r549 [0:5];
  reg signed [9:0] r550 [0:119];
  reg signed [9:0] r551 [0:0];
  reg signed [9:0] r552 [0:119];
  reg signed [9:0] r553 [0:0];
  reg signed [9:0] r554 [0:119];
  reg signed [6:0] r555 [0:5];
  reg signed [9:0] r556 [0:119];
  reg signed [9:0] r557 [0:0];
  reg signed [9:0] r558 [0:119];
  reg signed [9:0] r559 [0:0];
  reg signed [9:0] r560 [0:119];
  reg signed [9:0] r561 [0:119];
  reg signed [9:0] r562 [0:19];
  reg signed [9:0] r563 [0:19];
  reg signed [31:0] r564 [0:119];
  reg signed [31:0] r565 [0:0];
  reg signed [31:0] r566 [0:0];
  reg signed [31:0] r567 [0:19];
  reg signed [31:0] r568 [0:19];
  reg signed [4:0] r569 [0:0];
  reg signed [10:0] r570 [0:19];
  reg signed [9:0] r571 [0:19];
  reg signed [9:0] r572 [0:19];
  reg signed [10:0] r573 [0:119];
  reg signed [10:0] r574 [0:119];
  reg signed [13:0] r575 [0:19];
  reg signed [9:0] r576 [0:119];
  reg signed [9:0] r577 [0:19];
  reg signed [10:0] r578 [0:119];
  reg signed [10:0] r579 [0:119];
  reg signed [13:0] r580 [0:19];
  reg signed [14:0] r581 [0:19];
  reg r582 [0:19];
  reg signed [9:0] r583 [0:19];
  reg signed [9:0] r584 [0:19];
  reg signed [9:0] r585 [0:0];
  reg signed [9:0] r586 [0:19];
  reg signed [9:0] r587 [0:19];
  reg signed [9:0] r588 [0:119];
  reg signed [9:0] r589 [0:19];
  reg signed [9:0] r590 [0:19];
  reg signed [31:0] r591 [0:119];
  reg signed [31:0] r592 [0:0];
  reg signed [31:0] r593 [0:0];
  reg signed [31:0] r594 [0:19];
  reg signed [31:0] r595 [0:19];
  reg signed [4:0] r596 [0:0];
  reg signed [10:0] r597 [0:19];
  reg signed [9:0] r598 [0:19];
  reg signed [9:0] r599 [0:19];
  reg signed [10:0] r600 [0:119];
  reg signed [10:0] r601 [0:119];
  reg signed [13:0] r602 [0:19];
  reg signed [9:0] r603 [0:119];
  reg signed [9:0] r604 [0:19];
  reg signed [10:0] r605 [0:119];
  reg signed [10:0] r606 [0:119];
  reg signed [13:0] r607 [0:19];
  reg signed [14:0] r608 [0:19];
  reg r609 [0:19];
  reg signed [9:0] r610 [0:19];
  reg signed [9:0] r611 [0:19];
  reg signed [9:0] r612 [0:0];
  reg signed [9:0] r613 [0:19];
  reg signed [9:0] r614 [0:19];
  reg signed [10:0] r615 [0:19];
  reg signed [9:0] r616 [0:19];
  reg signed [7:0] r617 [0:0];
  reg signed [9:0] r618 [0:19];
  reg signed [7:0] r619 [0:0];
  reg signed [7:0] r620 [0:19];
  reg signed [6:0] r621 [0:0];
  reg signed [6:0] r622 [0:0];
  reg signed [6:0] r623 [0:0];
  reg signed [5:0] r624 [0:0];
  reg signed [7:0] r625 [0:34];
  reg signed [8:0] r626 [0:34];
  reg signed [5:0] r627 [0:79];
  reg signed [5:0] r628 [0:79];
  reg signed [5:0] r629 [0:79];
  reg signed [5:0] r630 [0:19];
  reg signed [5:0] r631 [0:19];
  reg signed [4:0] r632 [0:15];
  reg signed [4:0] r633 [0:15];
  reg signed [6:0] r634 [0:319];
  reg r635 [0:319];
  reg signed [7:0] r637 [0:319];
  reg signed [6:0] r638 [0:319];
  reg signed [6:0] r639 [0:319];
  reg signed [8:0] r640 [0:319];
  reg signed [8:0] r641 [0:319];
  reg signed [9:0] r642 [0:1599];
  reg signed [9:0] r643 [0:0];
  reg signed [9:0] r644 [0:1599];
  reg signed [9:0] r645 [0:0];
  reg signed [9:0] r646 [0:1599];
  reg signed [9:0] r647 [0:1599];
  reg signed [9:0] r648 [0:0];
  reg signed [9:0] r649 [0:1599];
  reg signed [9:0] r650 [0:0];
  reg signed [9:0] r651 [0:1599];
  reg signed [9:0] r652 [0:1599];
  reg signed [9:0] r653 [0:99];
  reg signed [9:0] r654 [0:99];
  reg signed [31:0] r655 [0:1599];
  reg signed [31:0] r656 [0:0];
  reg signed [31:0] r657 [0:0];
  reg signed [31:0] r658 [0:99];
  reg signed [31:0] r659 [0:99];
  reg signed [4:0] r660 [0:0];
  reg signed [10:0] r661 [0:99];
  reg signed [9:0] r662 [0:99];
  reg signed [9:0] r663 [0:99];
  reg signed [10:0] r664 [0:1599];
  reg signed [10:0] r665 [0:1599];
  reg signed [14:0] r666 [0:99];
  reg signed [9:0] r667 [0:1599];
  reg signed [9:0] r668 [0:99];
  reg signed [10:0] r669 [0:1599];
  reg signed [10:0] r670 [0:1599];
  reg signed [14:0] r671 [0:99];
  reg signed [15:0] r672 [0:99];
  reg r673 [0:99];
  reg signed [9:0] r674 [0:99];
  reg signed [9:0] r675 [0:99];
  reg signed [9:0] r676 [0:0];
  reg signed [9:0] r677 [0:99];
  reg signed [9:0] r678 [0:99];
  reg signed [9:0] r679 [0:1599];
  reg signed [9:0] r680 [0:99];
  reg signed [9:0] r681 [0:99];
  reg signed [31:0] r682 [0:1599];
  reg signed [31:0] r683 [0:0];
  reg signed [31:0] r684 [0:0];
  reg signed [31:0] r685 [0:99];
  reg signed [31:0] r686 [0:99];
  reg signed [4:0] r687 [0:0];
  reg signed [10:0] r688 [0:99];
  reg signed [9:0] r689 [0:99];
  reg signed [9:0] r690 [0:99];
  reg signed [10:0] r691 [0:1599];
  reg signed [10:0] r692 [0:1599];
  reg signed [14:0] r693 [0:99];
  reg signed [9:0] r694 [0:1599];
  reg signed [9:0] r695 [0:99];
  reg signed [10:0] r696 [0:1599];
  reg signed [10:0] r697 [0:1599];
  reg signed [14:0] r698 [0:99];
  reg signed [15:0] r699 [0:99];
  reg r700 [0:99];
  reg signed [9:0] r701 [0:99];
  reg signed [9:0] r702 [0:99];
  reg signed [9:0] r703 [0:0];
  reg signed [9:0] r704 [0:99];
  reg signed [9:0] r705 [0:99];
  reg signed [10:0] r706 [0:99];
  reg signed [10:0] r707 [0:99];
  reg signed [5:0] r708 [0:0];
  reg signed [10:0] r709 [0:99];
  reg signed [5:0] r710 [0:99];
  reg signed [5:0] r711 [0:0];
  reg r712 [0:99];
  reg signed [0:0] r713 [0:0];
  reg signed [0:0] r714 [0:99];
  reg signed [10:0] r715 [0:99];
  reg signed [14:0] r716 [0:4];
  reg signed [17:0] r718 [0:4];
  reg r719 [0:0];
  reg signed [6:0] r720 [0:0];
  reg signed [5:0] r721 [0:0];
  reg signed [5:0] r722 [0:0];
  reg signed [7:0] r723 [0:14];
  reg signed [31:0] r724 [0:0];
  reg signed [1:0] r725 [0:0];
  reg signed [7:0] r726 [0:24];
  reg signed [8:0] r727 [0:24];
  reg signed [0:0] r728 [0:0];
  reg signed [8:0] r729 [0:25];
  reg signed [4:0] r730 [0:9];
  reg signed [5:0] r731 [0:9];
  reg signed [5:0] r732 [0:9];
  reg signed [3:0] r733 [0:5];
  reg signed [3:0] r734 [0:5];
  reg signed [5:0] r735 [0:59];
  reg signed [5:0] r736 [0:59];
  reg signed [1:0] r737 [0:0];
  reg signed [5:0] r738 [0:59];
  reg r739 [0:59];
  reg signed [6:0] r741 [0:59];
  reg signed [5:0] r742 [0:59];
  reg signed [5:0] r743 [0:59];
  reg signed [8:0] r744 [0:59];
  reg signed [6:0] r745 [0:5];
  reg signed [6:0] r746 [0:5];
  reg signed [9:0] r747 [0:59];
  reg signed [9:0] r748 [0:0];
  reg signed [9:0] r749 [0:59];
  reg signed [9:0] r750 [0:0];
  reg signed [9:0] r751 [0:59];
  reg signed [6:0] r752 [0:5];
  reg signed [9:0] r753 [0:59];
  reg signed [9:0] r754 [0:0];
  reg signed [9:0] r755 [0:59];
  reg signed [9:0] r756 [0:0];
  reg signed [9:0] r757 [0:59];
  reg signed [9:0] r758 [0:59];
  reg signed [9:0] r759 [0:9];
  reg signed [9:0] r760 [0:9];
  reg signed [31:0] r761 [0:59];
  reg signed [31:0] r762 [0:0];
  reg signed [31:0] r763 [0:0];
  reg signed [31:0] r764 [0:9];
  reg signed [31:0] r765 [0:9];
  reg signed [4:0] r766 [0:0];
  reg signed [10:0] r767 [0:9];
  reg signed [9:0] r768 [0:9];
  reg signed [9:0] r769 [0:9];
  reg signed [10:0] r770 [0:59];
  reg signed [10:0] r771 [0:59];
  reg signed [13:0] r772 [0:9];
  reg signed [9:0] r773 [0:59];
  reg signed [9:0] r774 [0:9];
  reg signed [10:0] r775 [0:59];
  reg signed [10:0] r776 [0:59];
  reg signed [13:0] r777 [0:9];
  reg signed [14:0] r778 [0:9];
  reg r779 [0:9];
  reg signed [9:0] r780 [0:9];
  reg signed [9:0] r781 [0:9];
  reg signed [9:0] r782 [0:0];
  reg signed [9:0] r783 [0:9];
  reg signed [9:0] r784 [0:9];
  reg signed [9:0] r785 [0:59];
  reg signed [9:0] r786 [0:9];
  reg signed [9:0] r787 [0:9];
  reg signed [31:0] r788 [0:59];
  reg signed [31:0] r789 [0:0];
  reg signed [31:0] r790 [0:0];
  reg signed [31:0] r791 [0:9];
  reg signed [31:0] r792 [0:9];
  reg signed [4:0] r793 [0:0];
  reg signed [10:0] r794 [0:9];
  reg signed [9:0] r795 [0:9];
  reg signed [9:0] r796 [0:9];
  reg signed [10:0] r797 [0:59];
  reg signed [10:0] r798 [0:59];
  reg signed [13:0] r799 [0:9];
  reg signed [9:0] r800 [0:59];
  reg signed [9:0] r801 [0:9];
  reg signed [10:0] r802 [0:59];
  reg signed [10:0] r803 [0:59];
  reg signed [13:0] r804 [0:9];
  reg signed [14:0] r805 [0:9];
  reg r806 [0:9];
  reg signed [9:0] r807 [0:9];
  reg signed [9:0] r808 [0:9];
  reg signed [9:0] r809 [0:0];
  reg signed [9:0] r810 [0:9];
  reg signed [9:0] r811 [0:9];
  reg signed [10:0] r812 [0:9];
  reg signed [9:0] r813 [0:9];
  reg signed [7:0] r814 [0:0];
  reg signed [9:0] r815 [0:9];
  reg signed [7:0] r816 [0:0];
  reg signed [7:0] r817 [0:9];
  reg signed [5:0] r818 [0:0];
  reg signed [5:0] r819 [0:0];
  reg signed [5:0] r820 [0:0];
  reg signed [4:0] r821 [0:0];
  reg signed [7:0] r822 [0:24];
  reg signed [8:0] r823 [0:24];
  reg signed [5:0] r824 [0:79];
  reg signed [5:0] r825 [0:79];
  reg signed [5:0] r826 [0:79];
  reg signed [4:0] r827 [0:9];
  reg signed [4:0] r828 [0:9];
  reg signed [4:0] r829 [0:15];
  reg signed [4:0] r830 [0:15];
  reg signed [5:0] r831 [0:159];
  reg r832 [0:159];
  reg signed [6:0] r834 [0:159];
  reg signed [5:0] r835 [0:159];
  reg signed [5:0] r836 [0:159];
  reg signed [8:0] r837 [0:159];
  reg signed [8:0] r838 [0:159];
  reg signed [9:0] r839 [0:799];
  reg signed [9:0] r840 [0:0];
  reg signed [9:0] r841 [0:799];
  reg signed [9:0] r842 [0:0];
  reg signed [9:0] r843 [0:799];
  reg signed [9:0] r844 [0:799];
  reg signed [9:0] r845 [0:0];
  reg signed [9:0] r846 [0:799];
  reg signed [9:0] r847 [0:0];
  reg signed [9:0] r848 [0:799];
  reg signed [9:0] r849 [0:799];
  reg signed [9:0] r850 [0:49];
  reg signed [9:0] r851 [0:49];
  reg signed [31:0] r852 [0:799];
  reg signed [31:0] r853 [0:0];
  reg signed [31:0] r854 [0:0];
  reg signed [31:0] r855 [0:49];
  reg signed [31:0] r856 [0:49];
  reg signed [4:0] r857 [0:0];
  reg signed [10:0] r858 [0:49];
  reg signed [9:0] r859 [0:49];
  reg signed [9:0] r860 [0:49];
  reg signed [10:0] r861 [0:799];
  reg signed [10:0] r862 [0:799];
  reg signed [14:0] r863 [0:49];
  reg signed [9:0] r864 [0:799];
  reg signed [9:0] r865 [0:49];
  reg signed [10:0] r866 [0:799];
  reg signed [10:0] r867 [0:799];
  reg signed [14:0] r868 [0:49];
  reg signed [15:0] r869 [0:49];
  reg r870 [0:49];
  reg signed [9:0] r871 [0:49];
  reg signed [9:0] r872 [0:49];
  reg signed [9:0] r873 [0:0];
  reg signed [9:0] r874 [0:49];
  reg signed [9:0] r875 [0:49];
  reg signed [9:0] r876 [0:799];
  reg signed [9:0] r877 [0:49];
  reg signed [9:0] r878 [0:49];
  reg signed [31:0] r879 [0:799];
  reg signed [31:0] r880 [0:0];
  reg signed [31:0] r881 [0:0];
  reg signed [31:0] r882 [0:49];
  reg signed [31:0] r883 [0:49];
  reg signed [4:0] r884 [0:0];
  reg signed [10:0] r885 [0:49];
  reg signed [9:0] r886 [0:49];
  reg signed [9:0] r887 [0:49];
  reg signed [10:0] r888 [0:799];
  reg signed [10:0] r889 [0:799];
  reg signed [14:0] r890 [0:49];
  reg signed [9:0] r891 [0:799];
  reg signed [9:0] r892 [0:49];
  reg signed [10:0] r893 [0:799];
  reg signed [10:0] r894 [0:799];
  reg signed [14:0] r895 [0:49];
  reg signed [15:0] r896 [0:49];
  reg r897 [0:49];
  reg signed [9:0] r898 [0:49];
  reg signed [9:0] r899 [0:49];
  reg signed [9:0] r900 [0:0];
  reg signed [9:0] r901 [0:49];
  reg signed [9:0] r902 [0:49];
  reg signed [10:0] r903 [0:49];
  reg signed [10:0] r904 [0:49];
  reg signed [4:0] r905 [0:0];
  reg signed [10:0] r906 [0:49];
  reg signed [4:0] r907 [0:49];
  reg signed [4:0] r908 [0:0];
  reg r909 [0:49];
  reg signed [0:0] r910 [0:0];
  reg signed [0:0] r911 [0:49];
  reg signed [10:0] r912 [0:49];
  reg signed [13:0] r913 [0:4];
  reg signed [17:0] r915 [0:4];
  reg r916 [0:0];
  reg signed [6:0] r917 [0:0];
  reg signed [4:0] r918 [0:0];
  reg signed [4:0] r919 [0:0];
  reg signed [7:0] r920 [0:14];
  reg signed [31:0] r921 [0:0];
  reg signed [1:0] r922 [0:0];
  reg signed [7:0] r923 [0:14];
  reg signed [8:0] r924 [0:14];
  reg signed [0:0] r925 [0:0];
  reg signed [8:0] r926 [0:15];
  reg signed [3:0] r927 [0:4];
  reg signed [4:0] r928 [0:4];
  reg signed [4:0] r929 [0:4];
  reg signed [3:0] r930 [0:5];
  reg signed [3:0] r931 [0:5];
  reg signed [4:0] r932 [0:29];
  reg signed [4:0] r933 [0:29];
  reg signed [1:0] r934 [0:0];
  reg signed [4:0] r935 [0:29];
  reg r936 [0:29];
  reg signed [5:0] r938 [0:29];
  reg signed [4:0] r939 [0:29];
  reg signed [4:0] r940 [0:29];
  reg signed [8:0] r941 [0:29];
  reg signed [6:0] r942 [0:5];
  reg signed [6:0] r943 [0:5];
  reg signed [9:0] r944 [0:29];
  reg signed [9:0] r945 [0:0];
  reg signed [9:0] r946 [0:29];
  reg signed [9:0] r947 [0:0];
  reg signed [9:0] r948 [0:29];
  reg signed [6:0] r949 [0:5];
  reg signed [9:0] r950 [0:29];
  reg signed [9:0] r951 [0:0];
  reg signed [9:0] r952 [0:29];
  reg signed [9:0] r953 [0:0];
  reg signed [9:0] r954 [0:29];
  reg signed [9:0] r955 [0:29];
  reg signed [9:0] r956 [0:4];
  reg signed [9:0] r957 [0:4];
  reg signed [31:0] r958 [0:29];
  reg signed [31:0] r959 [0:0];
  reg signed [31:0] r960 [0:0];
  reg signed [31:0] r961 [0:4];
  reg signed [31:0] r962 [0:4];
  reg signed [4:0] r963 [0:0];
  reg signed [10:0] r964 [0:4];
  reg signed [9:0] r965 [0:4];
  reg signed [9:0] r966 [0:4];
  reg signed [10:0] r967 [0:29];
  reg signed [10:0] r968 [0:29];
  reg signed [13:0] r969 [0:4];
  reg signed [9:0] r970 [0:29];
  reg signed [9:0] r971 [0:4];
  reg signed [10:0] r972 [0:29];
  reg signed [10:0] r973 [0:29];
  reg signed [13:0] r974 [0:4];
  reg signed [14:0] r975 [0:4];
  reg r976 [0:4];
  reg signed [9:0] r977 [0:4];
  reg signed [9:0] r978 [0:4];
  reg signed [9:0] r979 [0:0];
  reg signed [9:0] r980 [0:4];
  reg signed [9:0] r981 [0:4];
  reg signed [9:0] r982 [0:29];
  reg signed [9:0] r983 [0:4];
  reg signed [9:0] r984 [0:4];
  reg signed [31:0] r985 [0:29];
  reg signed [31:0] r986 [0:0];
  reg signed [31:0] r987 [0:0];
  reg signed [31:0] r988 [0:4];
  reg signed [31:0] r989 [0:4];
  reg signed [4:0] r990 [0:0];
  reg signed [10:0] r991 [0:4];
  reg signed [9:0] r992 [0:4];
  reg signed [9:0] r993 [0:4];
  reg signed [10:0] r994 [0:29];
  reg signed [10:0] r995 [0:29];
  reg signed [13:0] r996 [0:4];
  reg signed [9:0] r997 [0:29];
  reg signed [9:0] r998 [0:4];
  reg signed [10:0] r999 [0:29];
  reg signed [10:0] r1000 [0:29];
  reg signed [13:0] r1001 [0:4];
  reg signed [14:0] r1002 [0:4];
  reg r1003 [0:4];
  reg signed [9:0] r1004 [0:4];
  reg signed [9:0] r1005 [0:4];
  reg signed [9:0] r1006 [0:0];
  reg signed [9:0] r1007 [0:4];
  reg signed [9:0] r1008 [0:4];
  reg signed [10:0] r1009 [0:4];
  reg signed [9:0] r1010 [0:4];
  reg signed [7:0] r1011 [0:0];
  reg signed [9:0] r1012 [0:4];
  reg signed [7:0] r1013 [0:0];
  reg signed [7:0] r1014 [0:4];
  reg signed [4:0] r1015 [0:0];
  reg signed [4:0] r1016 [0:0];
  reg signed [4:0] r1017 [0:0];
  reg signed [3:0] r1018 [0:0];
  reg signed [7:0] r1019 [0:19];
  reg signed [8:0] r1020 [0:19];
  reg signed [5:0] r1021 [0:79];
  reg signed [5:0] r1022 [0:79];
  reg signed [5:0] r1023 [0:79];
  reg signed [3:0] r1024 [0:4];
  reg signed [3:0] r1025 [0:4];
  reg signed [4:0] r1026 [0:15];
  reg signed [4:0] r1027 [0:15];
  reg signed [5:0] r1028 [0:79];
  reg r1029 [0:79];
  reg signed [6:0] r1031 [0:79];
  reg signed [5:0] r1032 [0:79];
  reg signed [5:0] r1033 [0:79];
  reg signed [8:0] r1034 [0:79];
  reg signed [8:0] r1035 [0:79];
  reg signed [9:0] r1036 [0:399];
  reg signed [9:0] r1037 [0:0];
  reg signed [9:0] r1038 [0:399];
  reg signed [9:0] r1039 [0:0];
  reg signed [9:0] r1040 [0:399];
  reg signed [9:0] r1041 [0:399];
  reg signed [9:0] r1042 [0:0];
  reg signed [9:0] r1043 [0:399];
  reg signed [9:0] r1044 [0:0];
  reg signed [9:0] r1045 [0:399];
  reg signed [9:0] r1046 [0:399];
  reg signed [9:0] r1047 [0:24];
  reg signed [9:0] r1048 [0:24];
  reg signed [31:0] r1049 [0:399];
  reg signed [31:0] r1050 [0:0];
  reg signed [31:0] r1051 [0:0];
  reg signed [31:0] r1052 [0:24];
  reg signed [31:0] r1053 [0:24];
  reg signed [4:0] r1054 [0:0];
  reg signed [10:0] r1055 [0:24];
  reg signed [9:0] r1056 [0:24];
  reg signed [9:0] r1057 [0:24];
  reg signed [10:0] r1058 [0:399];
  reg signed [10:0] r1059 [0:399];
  reg signed [14:0] r1060 [0:24];
  reg signed [9:0] r1061 [0:399];
  reg signed [9:0] r1062 [0:24];
  reg signed [10:0] r1063 [0:399];
  reg signed [10:0] r1064 [0:399];
  reg signed [14:0] r1065 [0:24];
  reg signed [15:0] r1066 [0:24];
  reg r1067 [0:24];
  reg signed [9:0] r1068 [0:24];
  reg signed [9:0] r1069 [0:24];
  reg signed [9:0] r1070 [0:0];
  reg signed [9:0] r1071 [0:24];
  reg signed [9:0] r1072 [0:24];
  reg signed [9:0] r1073 [0:399];
  reg signed [9:0] r1074 [0:24];
  reg signed [9:0] r1075 [0:24];
  reg signed [31:0] r1076 [0:399];
  reg signed [31:0] r1077 [0:0];
  reg signed [31:0] r1078 [0:0];
  reg signed [31:0] r1079 [0:24];
  reg signed [31:0] r1080 [0:24];
  reg signed [4:0] r1081 [0:0];
  reg signed [10:0] r1082 [0:24];
  reg signed [9:0] r1083 [0:24];
  reg signed [9:0] r1084 [0:24];
  reg signed [10:0] r1085 [0:399];
  reg signed [10:0] r1086 [0:399];
  reg signed [14:0] r1087 [0:24];
  reg signed [9:0] r1088 [0:399];
  reg signed [9:0] r1089 [0:24];
  reg signed [10:0] r1090 [0:399];
  reg signed [10:0] r1091 [0:399];
  reg signed [14:0] r1092 [0:24];
  reg signed [15:0] r1093 [0:24];
  reg r1094 [0:24];
  reg signed [9:0] r1095 [0:24];
  reg signed [9:0] r1096 [0:24];
  reg signed [9:0] r1097 [0:0];
  reg signed [9:0] r1098 [0:24];
  reg signed [9:0] r1099 [0:24];
  reg signed [10:0] r1100 [0:24];
  reg signed [10:0] r1101 [0:24];
  reg signed [3:0] r1102 [0:0];
  reg signed [10:0] r1103 [0:24];
  reg signed [3:0] r1104 [0:24];
  reg signed [3:0] r1105 [0:0];
  reg r1106 [0:24];
  reg signed [0:0] r1107 [0:0];
  reg signed [0:0] r1108 [0:24];
  reg signed [10:0] r1109 [0:24];
  reg signed [12:0] r1110 [0:4];
  reg signed [17:0] r1112 [0:4];
  reg r1113 [0:0];
  reg signed [5:0] r1114 [0:0];
  reg signed [3:0] r1115 [0:0];
  reg signed [3:0] r1116 [0:0];
  reg signed [7:0] r1117 [0:14];
  reg signed [31:0] r1118 [0:0];
  reg signed [17:0] r1119 [0:29];
  reg signed [23:0] r1120 [0:29];
  reg signed [31:0] r1121 [0:0];
  reg signed [0:0] r1122 [0:29];
  reg signed [0:0] r1123 [0:29];
  reg signed [23:0] r1124 [0:29];
  reg signed [2:0] r1125 [0:29];
  reg r1126 [0:29];
  reg signed [0:0] r1127 [0:29];
  reg signed [0:0] r1128 [0:29];
  reg signed [23:0] r1129 [0:29];
  reg signed [2:0] r1130 [0:29];
  reg signed [2:0] r1131 [0:29];
  reg signed [2:0] r1132 [0:29];
  reg signed [20:0] r1133 [0:29];
  reg r1134 [0:29];
  reg signed [20:0] r1135 [0:29];
  reg signed [2:0] r1136 [0:29];
  reg r1137 [0:29];
  reg signed [0:0] r1138 [0:29];
  reg signed [0:0] r1139 [0:29];
  reg signed [23:0] r1140 [0:29];
  reg signed [3:0] r1141 [0:29];
  reg signed [3:0] r1142 [0:29];
  reg signed [3:0] r1143 [0:29];
  reg signed [19:0] r1144 [0:29];
  reg r1145 [0:29];
  reg signed [20:0] r1146 [0:29];
  reg signed [0:0] r1147 [0:29];
  reg r1148 [0:29];
  reg signed [21:0] r1149 [0:29];
  reg r1150 [0:29];
  reg signed [20:0] r1151 [0:29];
  reg r1152 [0:29];
  reg signed [20:0] r1153 [0:29];
  reg r1154 [0:29];
  reg signed [20:0] r1155 [0:29];
  reg signed [7:0] r1156 [0:0];
  reg signed [20:0] r1157 [0:29];
  reg signed [7:0] r1158 [0:0];
  reg signed [7:0] r1159 [0:29];
  reg signed [8:0] r1160 [0:29];
  reg signed [8:0] r1161 [0:29];
  reg signed [8:0] r1162 [0:29];
  reg signed [8:0] r1163 [0:29];
  reg signed [5:0] r1164 [0:299];
  reg signed [5:0] r1165 [0:299];
  reg signed [5:0] r1166 [0:299];
  reg signed [9:0] r1167 [0:299];
  reg signed [9:0] r1168 [0:0];
  reg signed [9:0] r1169 [0:299];
  reg signed [9:0] r1170 [0:0];
  reg signed [9:0] r1171 [0:299];
  reg signed [5:0] r1172 [0:299];
  reg signed [8:0] r1173 [0:299];
  reg signed [9:0] r1174 [0:0];
  reg signed [9:0] r1175 [0:299];
  reg signed [9:0] r1176 [0:0];
  reg signed [9:0] r1177 [0:299];
  reg signed [9:0] r1178 [0:599];
  reg signed [0:0] r1179 [0:9];
  reg signed [0:0] r1180 [0:9];
  reg signed [9:0] r1181 [0:609];
  reg signed [9:0] r1182 [0:609];
  reg signed [9:0] r1183 [0:9];
  reg signed [9:0] r1185 [0:9];
  reg signed [31:0] r1186 [0:609];
  reg signed [31:0] r1187 [0:0];
  reg signed [31:0] r1188 [0:0];
  reg signed [31:0] r1189 [0:9];
  reg signed [31:0] r1190 [0:9];
  reg signed [4:0] r1191 [0:0];
  reg signed [10:0] r1192 [0:9];
  reg signed [9:0] r1193 [0:9];
  reg signed [9:0] r1194 [0:9];
  reg signed [10:0] r1195 [0:609];
  reg signed [10:0] r1196 [0:609];
  reg signed [16:0] r1197 [0:9];
  reg r1198 [0:9];
  reg signed [9:0] r1199 [0:9];
  reg signed [9:0] r1200 [0:9];
  reg signed [9:0] r1201 [0:0];
  reg signed [9:0] r1202 [0:9];
  reg signed [9:0] r1203 [0:9];
  reg signed [5:0] r1204 [0:299];
  reg signed [9:0] r1205 [0:299];
  reg signed [9:0] r1206 [0:0];
  reg signed [9:0] r1207 [0:299];
  reg signed [9:0] r1208 [0:0];
  reg signed [9:0] r1209 [0:299];
  reg signed [5:0] r1210 [0:299];
  reg signed [8:0] r1211 [0:299];
  reg signed [9:0] r1212 [0:0];
  reg signed [9:0] r1213 [0:299];
  reg signed [9:0] r1214 [0:0];
  reg signed [9:0] r1215 [0:299];
  reg signed [9:0] r1216 [0:599];
  reg signed [0:0] r1217 [0:9];
  reg signed [0:0] r1218 [0:9];
  reg signed [9:0] r1219 [0:609];
  reg signed [9:0] r1220 [0:609];
  reg signed [9:0] r1221 [0:9];
  reg signed [9:0] r1222 [0:9];
  reg signed [31:0] r1223 [0:609];
  reg signed [31:0] r1224 [0:0];
  reg signed [31:0] r1225 [0:0];
  reg signed [31:0] r1226 [0:9];
  reg signed [31:0] r1227 [0:9];
  reg signed [4:0] r1228 [0:0];
  reg signed [10:0] r1229 [0:9];
  reg signed [9:0] r1230 [0:9];
  reg signed [9:0] r1231 [0:9];
  reg signed [10:0] r1232 [0:609];
  reg signed [10:0] r1233 [0:609];
  reg signed [16:0] r1234 [0:9];
  reg r1235 [0:9];
  reg signed [9:0] r1236 [0:9];
  reg signed [9:0] r1237 [0:9];
  reg signed [9:0] r1238 [0:0];
  reg signed [9:0] r1239 [0:9];
  reg signed [9:0] r1240 [0:9];
  reg signed [9:0] r1241 [0:9];
  reg signed [9:0] r1242 [0:9];
  reg signed [9:0] r1243 [0:19];
  reg signed [9:0] r1244 [0:9];
  reg signed [10:0] r1246 [0:9];
  reg signed [31:0] r1247 [0:19];
  reg signed [31:0] r1248 [0:0];
  reg signed [31:0] r1249 [0:0];
  reg signed [31:0] r1250 [0:9];
  reg signed [31:0] r1251 [0:9];
  reg signed [4:0] r1252 [0:0];
  reg signed [11:0] r1253 [0:9];
  reg signed [10:0] r1254 [0:9];
  reg signed [10:0] r1255 [0:9];
  reg signed [10:0] r1256 [0:19];
  reg signed [10:0] r1257 [0:19];
  reg signed [11:0] r1258 [0:9];
  reg r1259 [0:9];
  reg signed [10:0] r1260 [0:9];
  reg signed [10:0] r1261 [0:9];
  reg signed [10:0] r1262 [0:0];
  reg signed [10:0] r1263 [0:9];
  reg signed [10:0] r1264 [0:9];
  reg signed [10:0] r1265 [0:9];
  reg signed [10:0] r1266 [0:9];
  reg signed [10:0] r1267 [0:9];
  reg signed [10:0] r1268 [0:9];
  reg signed [10:0] r1269 [0:9];
  reg signed [31:0] rom0_c [0:79];
  reg signed [31:0] rom1_c [0:5];
  reg signed [31:0] rom2_c [0:29];
  reg signed [31:0] rom3_c [0:29];
  reg signed [31:0] rom4_c [0:29];
  reg signed [31:0] rom5_c [0:299];
  reg signed [31:0] rom6_c [0:299];
  reg signed [31:0] rom7_c [0:9];
  reg signed [31:0] rom8_lit [0:0];
  reg signed [31:0] rom9_lit [0:0];
  reg signed [31:0] rom10_lit [0:0];
  reg signed [31:0] rom11_lit [0:0];
  reg signed [31:0] rom12_lit [0:0];
  reg signed [31:0] rom13_lit [0:0];
  reg signed [31:0] rom14_lit [0:0];
  reg signed [31:0] rom15_lit [0:0];
  reg signed [31:0] rom16_lit [0:0];
  reg signed [31:0] rom17_lit [0:0];
  reg signed [31:0] rom18_lit [0:0];
  reg signed [31:0] rom19_lit [0:0];
  reg signed [31:0] rom20_lit [0:0];
  reg signed [31:0] rom21_lit [0:0];
  reg signed [31:0] rom22_lit [0:0];
  reg signed [31:0] rom23_lit [0:0];
  reg signed [31:0] rom24_lit [0:0];
  reg signed [31:0] rom25_lit [0:0];
  reg signed [31:0] rom26_lit [0:0];
  reg signed [31:0] rom27_lit [0:0];
  reg signed [31:0] rom28_lit [0:0];
  reg signed [31:0] rom29_lit [0:0];
  reg signed [31:0] rom30_lit [0:0];
  reg signed [31:0] rom31_lit [0:0];
  reg signed [31:0] t0;
  reg signed [31:0] t1;
  reg signed [31:0] t2;
  reg signed [31:0] t3;
  reg signed [31:0] t4;
  reg signed [31:0] t5;
  reg signed [31:0] t6;
  reg signed [31:0] t7;
  reg signed [31:0] t8;
  reg signed [31:0] t9;
  integer a0;
  integer a1;
  integer a2;
  integer a3;
  integer c0;
  integer c1;
  integer c2;
  integer c3;
  integer k0;
  integer k1;
  integer k2;
  integer k3;
  integer k4;
  integer k5;
  integer k6;
  integer k7;
  integer k8;
  integer k9;
  integer k10;
  integer k11;
  integer k12;
  integer k13;
  integer k14;
  integer k15;
  integer k16;
  integer k17;
  integer k18;
  integer k19;
  integer k20;
  integer k21;
  integer k22;
  integer k23;
  integer k24;
  integer state;
  initial $readmemh("rom/rom0_c.mem", rom0_c);
  initial $readmemh("rom/rom1_c.mem", rom1_c);
  initial $readmemh("rom/rom2_c.mem", rom2_c);
  initial $readmemh("rom/rom3_c.mem", rom3_c);
  initial $readmemh("rom/rom4_c.mem", rom4_c);
  initial $readmemh("rom/rom5_c.mem", rom5_c);
  initial $readmemh("rom/rom6_c.mem", rom6_c);
  initial $readmemh("rom/rom7_c.mem", rom7_c);
  initial $readmemh("rom/rom8_lit.mem", rom8_lit);
  initial $readmemh("rom/rom9_lit.mem", rom9_lit);
  initial $readmemh("rom/rom10_lit.mem", rom10_lit);
  initial $readmemh("rom/rom11_lit.mem", rom11_lit);
  initial $readmemh("rom/rom12_lit.mem", rom12_lit);
  initial $readmemh("rom/rom13_lit.mem", rom13_lit);
  initial $readmemh("rom/rom14_lit.mem", rom14_lit);
  initial $readmemh("rom/rom15_lit.mem", rom15_lit);
  initial $readmemh("rom/rom16_lit.mem", rom16_lit);
  initial $readmemh("rom/rom17_lit.mem", rom17_lit);
  initial $readmemh("rom/rom18_lit.mem", rom18_lit);
  initial $readmemh("rom/rom19_lit.mem", rom19_lit);
  initial $readmemh("rom/rom20_lit.mem", rom20_lit);
  initial $readmemh("rom/rom21_lit.mem", rom21_lit);
  initial $readmemh("rom/rom22_lit.mem", rom22_lit);
  initial $readmemh("rom/rom23_lit.mem", rom23_lit);
  initial $readmemh("rom/rom24_lit.mem", rom24_lit);
  initial $readmemh("rom/rom25_lit.mem", rom25_lit);
  initial $readmemh("rom/rom26_lit.mem", rom26_lit);
  initial $readmemh("rom/rom27_lit.mem", rom27_lit);
  initial $readmemh("rom/rom28_lit.mem", rom28_lit);
  initial $readmemh("rom/rom29_lit.mem", rom29_lit);
  initial $readmemh("rom/rom30_lit.mem", rom30_lit);
  initial $readmemh("rom/rom31_lit.mem", rom31_lit);
  always @(posedge clk) begin
    if (rst) begin
      state <= 0;
      done <= 0;
    end else begin
      case (state)
      0: begin if (start) state <= 1; end
      1: begin  // instr 0 abs
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 160; c1 = c1 + 1) begin
            t0 = $signed(r16[a1]);
            t1 = (t0 < 0) ? (0 - t0) : t0;
            r26[a0] = t1[8:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 160;
        end
        state <= 2;
      end
      2: begin  // instr 1 reduce_max
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          r27[a0] = t0[8:0];
          a0 = a0 + 1;
        end
        state <= 3;
      end
      3: begin  // reduce.max.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 160; c1 = c1 + 1) begin
            t0 = $signed(r27[a0]);
            t1 = $signed(r26[a1]);
            t2 = (t0 < t1) ? t1 : t0;
            r27[a0] = t2[8:0];
            a1 = a1 + 1;
          end
          a0 = a0 + 1;
        end
        state <= 4;
      end
      4: begin  // instr 2 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r13[a1]);
          t1 = $signed(r27[a2]);
          t2 = (t0 < t1) ? t1 : t0;
          r28[a0] = t2[8:0];
          a0 = a0 + 1;
        end
        state <= 5;
      end
      5: begin  // instr 3 concat
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 15; c1 = c1 + 1) begin
            t0 = $signed(r0[a1]);
            r29[a0] = t0[7:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a0 = a0 + 160;
        end
        state <= 6;
      end
      6: begin  // concat
        a0 = 15;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 160; c1 = c1 + 1) begin
            t0 = $signed(r16[a1]);
            r29[a0] = t0[7:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a0 = a0 + 15;
        end
        state <= 7;
      end
      7: begin  // instr 4 shl
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 175; c1 = c1 + 1) begin
            t0 = $signed(r29[a1]);
            t1 = t0 << 1;
            r31[a0] = t1[8:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 175;
        end
        state <= 8;
      end
      8: begin  // instr 5 mov
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(rom0_c[a1]);
            t1 = t0;
            r32[a0] = t1[5:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
        end
        state <= 9;
      end
      9: begin  // instr 6 rev
        a0 = 0;
        a1 = 15;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(r32[a1]);
            r33[a0] = t0[5:0];
            a0 = a0 + 1;
            a1 = a1 - 1;
          end
          a1 = a1 + 32;
        end
        state <= 10;
      end
      10: begin  // instr 7 reshape
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 80; c0 = c0 + 1) begin
          t0 = $signed(r33[a1]);
          r34[a0] = t0[5:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 11;
      end
      11: begin  // instr 8 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 160; c0 = c0 + 1) begin
          t0 = a1;
          r35[a0] = t0[8:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 12;
      end
      12: begin  // instr 9 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 160; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            t0 = $signed(r35[a1]);
            r36[a0] = t0[8:0];
            a0 = a0 + 1;
          end
          a1 = a1 + 1;
        end
        state <= 13;
      end
      13: begin  // instr 10 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 16; c0 = c0 + 1) begin
          t0 = a1;
          r37[a0] = t0[4:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 14;
      end
      14: begin  // instr 11 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(r37[a1]);
            r38[a0] = t0[4:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 16;
        end
        state <= 15;
      end
      15: begin  // instr 12 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 160; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(r36[a1]);
            t1 = $signed(r38[a2]);
            t2 = t0 + t1;
            r39[a0] = t2[8:0];
            a0 = a0 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 + 1;
          a2 = a2 - 16;
        end
        state <= 16;
      end
      16: begin  // instr 13 lt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 160; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(r39[a1]);
            t1 = $signed(rom9_lit[a2]);
            t2 = (t0 < t1) ? 1 : 0;
            r41[a0] = (t2 != 0);
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
        end
        state <= 17;
      end
      17: begin  // instr 14 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 160; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(r39[a1]);
            t1 = $signed(rom10_lit[a2]);
            t2 = t0 + t1;
            r43[a0] = t2[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
        end
        state <= 18;
      end
      18: begin  // instr 15 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 160; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = r41[a1];
            t1 = $signed(r39[a2]);
            t2 = $signed(r43[a3]);
            t3 = (t0 != 0) ? t2 : t1;
            r44[a0] = t3[8:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
            a3 = a3 + 1;
          end
        end
        state <= 19;
      end
      19: begin  // instr 16 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 160; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r44[a1]);
              r45[a0] = t0[8:0];
              a0 = a0 + 1;
            end
            a1 = a1 + 1;
          end
        end
        state <= 20;
      end
      20: begin  // instr 17 gather
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 160; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 16; c2 = c2 + 1) begin
              t9 = 0;
              t0 = $signed(r45[a2]);
              t1 = (t0 < 0) ? 0 : t0;
              t1 = (t1 > 174) ? 174 : t1;
              t2 = t1;
              t9 = t9 + t2;
              t3 = $signed(r31[a1 + t9]);
              r46[a0] = t3[8:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a1 = a1 + 175;
          a2 = a2 - 2560;
        end
        state <= 21;
      end
      21: begin  // instr 18 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 160; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r46[a1]);
                r47[a0] = t0[8:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 2560;
          end
        end
        state <= 22;
      end
      22: begin  // instr 19 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 160; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r34[a1]);
                t1 = $signed(r47[a2]);
                t2 = t0 + t1;
                r48[a0] = t2[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
                a2 = a2 + 1;
              end
              a1 = a1 - 16;
            end
            a2 = a2 - 2560;
          end
          a1 = a1 + 16;
        end
        state <= 23;
      end
      23: begin  // instr 20 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom11_lit[a1]);
        t1 = t0;
        r51[a0] = t1[9:0];
        state <= 24;
      end
      24: begin  // instr 21 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 160; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r51[a1]);
                t1 = $signed(r48[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r52[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 2560;
          end
          a2 = a2 + 2560;
        end
        state <= 25;
      end
      25: begin  // instr 22 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom12_lit[a1]);
        t1 = t0;
        r53[a0] = t1[9:0];
        state <= 26;
      end
      26: begin  // instr 23 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 160; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r53[a1]);
                t1 = $signed(r52[a2]);
                t2 = (t1 < t0) ? t1 : t0;
                r54[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 2560;
          end
          a2 = a2 + 2560;
        end
        state <= 27;
      end
      27: begin  // instr 24 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 160; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r34[a1]);
                t1 = $signed(r47[a2]);
                t2 = t0 - t1;
                r55[a0] = t2[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
                a2 = a2 + 1;
              end
              a1 = a1 - 16;
            end
            a2 = a2 - 2560;
          end
          a1 = a1 + 16;
        end
        state <= 28;
      end
      28: begin  // instr 25 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom11_lit[a1]);
        t1 = t0;
        r56[a0] = t1[9:0];
        state <= 29;
      end
      29: begin  // instr 26 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 160; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r56[a1]);
                t1 = $signed(r55[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r57[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 2560;
          end
          a2 = a2 + 2560;
        end
        state <= 30;
      end
      30: begin  // instr 27 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom12_lit[a1]);
        t1 = t0;
        r58[a0] = t1[9:0];
        state <= 31;
      end
      31: begin  // instr 28 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 160; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r58[a1]);
                t1 = $signed(r57[a2]);
                t2 = (t1 < t0) ? t1 : t0;
                r59[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 2560;
          end
          a2 = a2 + 2560;
        end
        state <= 32;
      end
      32: begin  // instr 29 abs
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 160; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r54[a1]);
                t1 = (t0 < 0) ? (0 - t0) : t0;
                r60[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 2560;
          end
          a1 = a1 + 2560;
        end
        state <= 33;
      end
      33: begin  // instr 30 reduce_max
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 800; c0 = c0 + 1) begin
          r61[a0] = t0[9:0];
          a0 = a0 + 1;
        end
        state <= 34;
      end
      34: begin  // reduce.max.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 160; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r61[a0]);
                t1 = $signed(r60[a1]);
                t2 = (t0 < t1) ? t1 : t0;
                r61[a0] = t2[9:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 35;
      end
      35: begin  // instr 31 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 160; c2 = c2 + 1) begin
              t0 = $signed(r61[a1]);
              t1 = $signed(rom13_lit[a2]);
              t2 = t0 - t1;
              r63[a0] = t2[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 160;
          end
          a1 = a1 + 160;
        end
        state <= 36;
      end
      36: begin  // instr 32 loop
        k0 = 0;
        state <= 37;
      end
      37: begin  // loop0.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 12800; c0 = c0 + 1) begin
          t0 = $signed(r54[a1]);
          r64[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 38;
      end
      38: begin  // loop0.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom13_lit[a1]);
          r65[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 39;
      end
      39: begin  // loop0.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom9_lit[a1]);
          r66[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 40;
      end
      40: begin  // loop0.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 800; c0 = c0 + 1) begin
          t0 = $signed(r63[a1]);
          r67[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 41;
      end
      41: begin  // loop0.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 800; c0 = c0 + 1) begin
          t0 = $signed(r61[a1]);
          r68[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 42;
      end
      42: begin  // loop0.head
        if (k0 == 12) state <= 65;
        else state <= 43;
      end
      43: begin  // instr 33 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r66[a1]);
        t1 = $signed(rom8_lit[a2]);
        t2 = t0 + t1;
        r69[a0] = t2[4:0];
        state <= 44;
      end
      44: begin  // instr 34 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 160; c2 = c2 + 1) begin
              t0 = $signed(r67[a1]);
              t1 = $signed(r68[a2]);
              t2 = t0 + t1;
              r70[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 160;
            a2 = a2 - 160;
          end
          a1 = a1 + 160;
          a2 = a2 + 160;
        end
        state <= 45;
      end
      45: begin  // instr 35 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 160; c2 = c2 + 1) begin
              t0 = $signed(r70[a1]);
              t1 = t0 >>> 1;
              r71[a0] = t1[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 160;
          end
          a1 = a1 + 160;
        end
        state <= 46;
      end
      46: begin  // instr 36 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 160; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r71[a1]);
                r72[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 160;
          end
          a1 = a1 + 160;
        end
        state <= 47;
      end
      47: begin  // instr 37 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 160; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r64[a1]);
                t1 = $signed(r72[a2]);
                t2 = t0 - t1;
                r73[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 2560;
            a2 = a2 - 160;
          end
          a1 = a1 + 2560;
          a2 = a2 + 160;
        end
        state <= 48;
      end
      48: begin  // instr 38 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 160; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r73[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r74[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 2560;
          end
          a1 = a1 + 2560;
        end
        state <= 49;
      end
      49: begin  // instr 39 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 800; c0 = c0 + 1) begin
          r75[a0] = t0[14:0];
          a0 = a0 + 1;
        end
        state <= 50;
      end
      50: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 160; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r75[a0]);
                t1 = $signed(r74[a1]);
                t2 = t0 + t1;
                r75[a0] = t2[14:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 51;
      end
      51: begin  // instr 40 neg
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 160; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r64[a1]);
                t1 = 0 - t0;
                r76[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 2560;
          end
          a1 = a1 + 2560;
        end
        state <= 52;
      end
      52: begin  // instr 41 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 160; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r71[a1]);
                r77[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 160;
          end
          a1 = a1 + 160;
        end
        state <= 53;
      end
      53: begin  // instr 42 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 160; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r76[a1]);
                t1 = $signed(r77[a2]);
                t2 = t0 - t1;
                r78[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 2560;
            a2 = a2 - 160;
          end
          a1 = a1 + 2560;
          a2 = a2 + 160;
        end
        state <= 54;
      end
      54: begin  // instr 43 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 160; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r78[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r79[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 2560;
          end
          a1 = a1 + 2560;
        end
        state <= 55;
      end
      55: begin  // instr 44 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 800; c0 = c0 + 1) begin
          r80[a0] = t0[14:0];
          a0 = a0 + 1;
        end
        state <= 56;
      end
      56: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 160; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r80[a0]);
                t1 = $signed(r79[a1]);
                t2 = t0 + t1;
                r80[a0] = t2[14:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 57;
      end
      57: begin  // instr 45 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 160; c2 = c2 + 1) begin
              t0 = $signed(r75[a1]);
              t1 = $signed(r80[a2]);
              t2 = t0 + t1;
              r81[a0] = t2[15:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 160;
            a2 = a2 - 160;
          end
          a1 = a1 + 160;
          a2 = a2 + 160;
        end
        state <= 58;
      end
      58: begin  // instr 46 gt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 160; c2 = c2 + 1) begin
              t0 = $signed(r81[a1]);
              t1 = $signed(r65[a2]);
              t2 = (t0 > t1) ? 1 : 0;
              r82[a0] = (t2 != 0);
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 160;
          end
          a1 = a1 + 160;
        end
        state <= 59;
      end
      59: begin  // instr 47 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 160; c2 = c2 + 1) begin
              t0 = r82[a1];
              t1 = $signed(r67[a2]);
              t2 = $signed(r71[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r83[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 160;
            a2 = a2 - 160;
            a3 = a3 - 160;
          end
          a1 = a1 + 160;
          a2 = a2 + 160;
          a3 = a3 + 160;
        end
        state <= 60;
      end
      60: begin  // instr 48 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 160; c2 = c2 + 1) begin
              t0 = r82[a1];
              t1 = $signed(r71[a2]);
              t2 = $signed(r68[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r84[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 160;
            a2 = a2 - 160;
            a3 = a3 - 160;
          end
          a1 = a1 + 160;
          a2 = a2 + 160;
          a3 = a3 + 160;
        end
        state <= 61;
      end
      61: begin  // loop0.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r69[a1]);
          r66[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 62;
      end
      62: begin  // loop0.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 800; c0 = c0 + 1) begin
          t0 = $signed(r83[a1]);
          r67[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 63;
      end
      63: begin  // loop0.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 800; c0 = c0 + 1) begin
          t0 = $signed(r84[a1]);
          r68[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 64;
      end
      64: begin  // loop0.adv
        k0 = k0 + 1;
        state <= 42;
      end
      65: begin  // loop0.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r66[a1]);
          r85[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 66;
      end
      66: begin  // loop0.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 800; c0 = c0 + 1) begin
          t0 = $signed(r67[a1]);
          r86[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 67;
      end
      67: begin  // loop0.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 800; c0 = c0 + 1) begin
          t0 = $signed(r68[a1]);
          r87[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 68;
      end
      68: begin  // instr 49 abs
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 160; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r59[a1]);
                t1 = (t0 < 0) ? (0 - t0) : t0;
                r88[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 2560;
          end
          a1 = a1 + 2560;
        end
        state <= 69;
      end
      69: begin  // instr 50 reduce_max
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 800; c0 = c0 + 1) begin
          r89[a0] = t0[9:0];
          a0 = a0 + 1;
        end
        state <= 70;
      end
      70: begin  // reduce.max.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 160; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r89[a0]);
                t1 = $signed(r88[a1]);
                t2 = (t0 < t1) ? t1 : t0;
                r89[a0] = t2[9:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 71;
      end
      71: begin  // instr 51 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 160; c2 = c2 + 1) begin
              t0 = $signed(r89[a1]);
              t1 = $signed(rom13_lit[a2]);
              t2 = t0 - t1;
              r90[a0] = t2[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 160;
          end
          a1 = a1 + 160;
        end
        state <= 72;
      end
      72: begin  // instr 52 loop
        k1 = 0;
        state <= 73;
      end
      73: begin  // loop1.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 12800; c0 = c0 + 1) begin
          t0 = $signed(r59[a1]);
          r91[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 74;
      end
      74: begin  // loop1.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom13_lit[a1]);
          r92[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 75;
      end
      75: begin  // loop1.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom9_lit[a1]);
          r93[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 76;
      end
      76: begin  // loop1.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 800; c0 = c0 + 1) begin
          t0 = $signed(r90[a1]);
          r94[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 77;
      end
      77: begin  // loop1.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 800; c0 = c0 + 1) begin
          t0 = $signed(r89[a1]);
          r95[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 78;
      end
      78: begin  // loop1.head
        if (k1 == 12) state <= 101;
        else state <= 79;
      end
      79: begin  // instr 53 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r93[a1]);
        t1 = $signed(rom8_lit[a2]);
        t2 = t0 + t1;
        r96[a0] = t2[4:0];
        state <= 80;
      end
      80: begin  // instr 54 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 160; c2 = c2 + 1) begin
              t0 = $signed(r94[a1]);
              t1 = $signed(r95[a2]);
              t2 = t0 + t1;
              r97[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 160;
            a2 = a2 - 160;
          end
          a1 = a1 + 160;
          a2 = a2 + 160;
        end
        state <= 81;
      end
      81: begin  // instr 55 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 160; c2 = c2 + 1) begin
              t0 = $signed(r97[a1]);
              t1 = t0 >>> 1;
              r98[a0] = t1[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 160;
          end
          a1 = a1 + 160;
        end
        state <= 82;
      end
      82: begin  // instr 56 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 160; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r98[a1]);
                r99[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 160;
          end
          a1 = a1 + 160;
        end
        state <= 83;
      end
      83: begin  // instr 57 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 160; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r91[a1]);
                t1 = $signed(r99[a2]);
                t2 = t0 - t1;
                r100[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 2560;
            a2 = a2 - 160;
          end
          a1 = a1 + 2560;
          a2 = a2 + 160;
        end
        state <= 84;
      end
      84: begin  // instr 58 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 160; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r100[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r101[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 2560;
          end
          a1 = a1 + 2560;
        end
        state <= 85;
      end
      85: begin  // instr 59 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 800; c0 = c0 + 1) begin
          r102[a0] = t0[14:0];
          a0 = a0 + 1;
        end
        state <= 86;
      end
      86: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 160; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r102[a0]);
                t1 = $signed(r101[a1]);
                t2 = t0 + t1;
                r102[a0] = t2[14:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 87;
      end
      87: begin  // instr 60 neg
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 160; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r91[a1]);
                t1 = 0 - t0;
                r103[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 2560;
          end
          a1 = a1 + 2560;
        end
        state <= 88;
      end
      88: begin  // instr 61 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 160; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r98[a1]);
                r104[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 160;
          end
          a1 = a1 + 160;
        end
        state <= 89;
      end
      89: begin  // instr 62 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 160; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r103[a1]);
                t1 = $signed(r104[a2]);
                t2 = t0 - t1;
                r105[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 2560;
            a2 = a2 - 160;
          end
          a1 = a1 + 2560;
          a2 = a2 + 160;
        end
        state <= 90;
      end
      90: begin  // instr 63 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 160; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r105[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r106[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 2560;
          end
          a1 = a1 + 2560;
        end
        state <= 91;
      end
      91: begin  // instr 64 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 800; c0 = c0 + 1) begin
          r107[a0] = t0[14:0];
          a0 = a0 + 1;
        end
        state <= 92;
      end
      92: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 160; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r107[a0]);
                t1 = $signed(r106[a1]);
                t2 = t0 + t1;
                r107[a0] = t2[14:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 93;
      end
      93: begin  // instr 65 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 160; c2 = c2 + 1) begin
              t0 = $signed(r102[a1]);
              t1 = $signed(r107[a2]);
              t2 = t0 + t1;
              r108[a0] = t2[15:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 160;
            a2 = a2 - 160;
          end
          a1 = a1 + 160;
          a2 = a2 + 160;
        end
        state <= 94;
      end
      94: begin  // instr 66 gt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 160; c2 = c2 + 1) begin
              t0 = $signed(r108[a1]);
              t1 = $signed(r92[a2]);
              t2 = (t0 > t1) ? 1 : 0;
              r109[a0] = (t2 != 0);
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 160;
          end
          a1 = a1 + 160;
        end
        state <= 95;
      end
      95: begin  // instr 67 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 160; c2 = c2 + 1) begin
              t0 = r109[a1];
              t1 = $signed(r94[a2]);
              t2 = $signed(r98[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r110[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 160;
            a2 = a2 - 160;
            a3 = a3 - 160;
          end
          a1 = a1 + 160;
          a2 = a2 + 160;
          a3 = a3 + 160;
        end
        state <= 96;
      end
      96: begin  // instr 68 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 160; c2 = c2 + 1) begin
              t0 = r109[a1];
              t1 = $signed(r98[a2]);
              t2 = $signed(r95[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r111[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 160;
            a2 = a2 - 160;
            a3 = a3 - 160;
          end
          a1 = a1 + 160;
          a2 = a2 + 160;
          a3 = a3 + 160;
        end
        state <= 97;
      end
      97: begin  // loop1.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r96[a1]);
          r93[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 98;
      end
      98: begin  // loop1.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 800; c0 = c0 + 1) begin
          t0 = $signed(r110[a1]);
          r94[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 99;
      end
      99: begin  // loop1.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 800; c0 = c0 + 1) begin
          t0 = $signed(r111[a1]);
          r95[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 100;
      end
      100: begin  // loop1.adv
        k1 = k1 + 1;
        state <= 78;
      end
      101: begin  // loop1.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r93[a1]);
          r112[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 102;
      end
      102: begin  // loop1.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 800; c0 = c0 + 1) begin
          t0 = $signed(r94[a1]);
          r113[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 103;
      end
      103: begin  // loop1.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 800; c0 = c0 + 1) begin
          t0 = $signed(r95[a1]);
          r114[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 104;
      end
      104: begin  // instr 69 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 160; c2 = c2 + 1) begin
              t0 = $signed(r87[a1]);
              t1 = $signed(r114[a2]);
              t2 = t0 - t1;
              r115[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 160;
            a2 = a2 - 160;
          end
          a1 = a1 + 160;
          a2 = a2 + 160;
        end
        state <= 105;
      end
      105: begin  // instr 70 transpose
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 160; c2 = c2 + 1) begin
              t0 = $signed(r115[a1]);
              r116[a0] = t0[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 640;
        end
        state <= 106;
      end
      106: begin  // instr 71 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            t0 = $signed(r17[a1]);
            r117[a0] = t0[8:0];
            a0 = a0 + 1;
          end
        end
        state <= 107;
      end
      107: begin  // instr 72 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 160; c2 = c2 + 1) begin
              t0 = $signed(r116[a1]);
              t1 = $signed(rom9_lit[a2]);
              t2 = (t0 < t1) ? t1 : t0;
              r118[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 800;
        end
        state <= 108;
      end
      108: begin  // instr 73 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 160; c2 = c2 + 1) begin
              t0 = a1;
              r119[a0] = t0[8:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 160;
          end
        end
        state <= 109;
      end
      109: begin  // instr 74 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r117[a1]);
              r120[a0] = t0[8:0];
              a0 = a0 + 1;
            end
          end
        end
        state <= 110;
      end
      110: begin  // instr 75 lt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 160; c2 = c2 + 1) begin
              t0 = $signed(r119[a1]);
              t1 = $signed(r120[a2]);
              t2 = (t0 < t1) ? 1 : 0;
              r121[a0] = (t2 != 0);
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 800;
        end
        state <= 111;
      end
      111: begin  // instr 76 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom9_lit[a1]);
        t1 = t0;
        r122[a0] = t1[0:0];
        state <= 112;
      end
      112: begin  // instr 77 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 160; c2 = c2 + 1) begin
              t0 = $signed(r122[a1]);
              r123[a0] = t0[0:0];
              a0 = a0 + 1;
            end
          end
        end
        state <= 113;
      end
      113: begin  // instr 78 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 160; c2 = c2 + 1) begin
              t0 = r121[a1];
              t1 = $signed(r123[a2]);
              t2 = $signed(r118[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r124[a0] = t3[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
          end
          a1 = a1 - 800;
          a2 = a2 - 800;
          a3 = a3 - 800;
        end
        state <= 114;
      end
      114: begin  // instr 79 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          r125[a0] = t0[17:0];
          a0 = a0 + 1;
        end
        state <= 115;
      end
      115: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 160; c2 = c2 + 1) begin
              t0 = $signed(r125[a0]);
              t1 = $signed(r124[a1]);
              t2 = t0 + t1;
              r125[a0] = t2[17:0];
              a1 = a1 + 1;
            end
            a0 = a0 + 1;
          end
        end
        state <= 116;
      end
      116: begin  // instr 80 shl
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            t0 = $signed(r125[a1]);
            t1 = t0 << 0;
            r126[a0] = t1[17:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 5;
        end
        state <= 117;
      end
      117: begin  // instr 81 lt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r17[a1]);
          t1 = $signed(rom9_lit[a2]);
          t2 = (t0 < t1) ? 1 : 0;
          r127[a0] = (t2 != 0);
          a0 = a0 + 1;
        end
        state <= 118;
      end
      118: begin  // instr 82 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r17[a1]);
          t1 = $signed(rom10_lit[a2]);
          t2 = t0 + t1;
          r128[a0] = t2[9:0];
          a0 = a0 + 1;
        end
        state <= 119;
      end
      119: begin  // instr 83 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = r127[a1];
          t1 = $signed(r17[a2]);
          t2 = $signed(r128[a3]);
          t3 = (t0 != 0) ? t2 : t1;
          r129[a0] = t3[8:0];
          a0 = a0 + 1;
        end
        state <= 120;
      end
      120: begin  // instr 84 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            t0 = $signed(r129[a1]);
            r130[a0] = t0[8:0];
            a0 = a0 + 1;
          end
        end
        state <= 121;
      end
      121: begin  // instr 85 gather
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 15; c1 = c1 + 1) begin
            t9 = 0;
            t0 = $signed(r130[a2]);
            t1 = (t0 < 0) ? 0 : t0;
            t1 = (t1 > 160) ? 160 : t1;
            t2 = t1;
            t9 = t9 + t2;
            t3 = $signed(r29[a1 + t9]);
            r131[a0] = t3[7:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 + 160;
          a2 = a2 + 1;
        end
        state <= 122;
      end
      122: begin  // instr 86 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r6[a1]);
          t1 = $signed(r17[a2]);
          t2 = t0 + t1;
          r132[a0] = t2;
          a0 = a0 + 1;
        end
        state <= 123;
      end
      123: begin  // instr 87 and
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r6[a1]);
          t1 = $signed(rom8_lit[a2]);
          t2 = t0 & t1;
          r133[a0] = t2[1:0];
          a0 = a0 + 1;
        end
        state <= 124;
      end
      124: begin  // instr 88 slice
        a0 = 0;
        a1 = 10;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 165; c1 = c1 + 1) begin
            t0 = $signed(r29[a1]);
            r134[a0] = t0[7:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 + 10;
        end
        state <= 125;
      end
      125: begin  // instr 89 shl
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 165; c1 = c1 + 1) begin
            t0 = $signed(r134[a1]);
            t1 = t0 << 1;
            r135[a0] = t1[8:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 165;
        end
        state <= 126;
      end
      126: begin  // instr 90 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom9_lit[a1]);
        t1 = t0;
        r136[a0] = t1[0:0];
        state <= 127;
      end
      127: begin  // instr 91 pad
        t0 = $signed(r136[0]);
        a0 = 0;
        for (c0 = 0; c0 < 166; c0 = c0 + 1) begin
          r137[a0] = t0[8:0];
          a0 = a0 + 1;
        end
        state <= 128;
      end
      128: begin  // pad.scatter
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 165; c1 = c1 + 1) begin
            t1 = $signed(r135[a1]);
            r137[a0] = t1[8:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a0 = a0 + 1;
        end
        state <= 129;
      end
      129: begin  // instr 92 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 80; c0 = c0 + 1) begin
          t0 = a1;
          r138[a0] = t0[7:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 130;
      end
      130: begin  // instr 93 shl
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 80; c0 = c0 + 1) begin
          t0 = $signed(r138[a1]);
          t1 = t0 << 1;
          r139[a0] = t1[8:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 131;
      end
      131: begin  // instr 94 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 80; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            t0 = $signed(r139[a1]);
            r140[a0] = t0[8:0];
            a0 = a0 + 1;
          end
          a1 = a1 + 1;
        end
        state <= 132;
      end
      132: begin  // instr 95 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 6; c0 = c0 + 1) begin
          t0 = a1;
          r141[a0] = t0[3:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 133;
      end
      133: begin  // instr 96 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 6; c1 = c1 + 1) begin
            t0 = $signed(r141[a1]);
            r142[a0] = t0[3:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 6;
        end
        state <= 134;
      end
      134: begin  // instr 97 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 80; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 6; c1 = c1 + 1) begin
            t0 = $signed(r140[a1]);
            t1 = $signed(r142[a2]);
            t2 = t0 + t1;
            r143[a0] = t2[8:0];
            a0 = a0 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 + 1;
          a2 = a2 - 6;
        end
        state <= 135;
      end
      135: begin  // instr 98 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 80; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r143[a1]);
              r144[a0] = t0[8:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 480;
        end
        state <= 136;
      end
      136: begin  // instr 99 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r133[a1]);
              r145[a0] = t0[1:0];
              a0 = a0 + 1;
            end
          end
        end
        state <= 137;
      end
      137: begin  // instr 100 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 80; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r145[a1]);
              t1 = $signed(r144[a2]);
              t2 = t0 + t1;
              r146[a0] = t2[8:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a2 = a2 - 480;
        end
        state <= 138;
      end
      138: begin  // instr 101 lt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 80; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r146[a1]);
              t1 = $signed(rom9_lit[a2]);
              t2 = (t0 < t1) ? 1 : 0;
              r147[a0] = (t2 != 0);
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 480;
        end
        state <= 139;
      end
      139: begin  // instr 102 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 80; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r146[a1]);
              t1 = $signed(rom14_lit[a2]);
              t2 = t0 + t1;
              r149[a0] = t2[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 480;
        end
        state <= 140;
      end
      140: begin  // instr 103 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 80; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = r147[a1];
              t1 = $signed(r146[a2]);
              t2 = $signed(r149[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r150[a0] = t3[8:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
          end
          a1 = a1 - 480;
          a2 = a2 - 480;
          a3 = a3 - 480;
        end
        state <= 141;
      end
      141: begin  // instr 104 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 80; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r150[a1]);
                r151[a0] = t0[8:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 480;
        end
        state <= 142;
      end
      142: begin  // instr 105 gather
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 80; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t9 = 0;
              t0 = $signed(r151[a2]);
              t1 = (t0 < 0) ? 0 : t0;
              t1 = (t1 > 165) ? 165 : t1;
              t2 = t1;
              t9 = t9 + t2;
              t3 = $signed(r137[a1 + t9]);
              r152[a0] = t3[8:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a1 = a1 + 166;
        end
        state <= 143;
      end
      143: begin  // instr 106 mov
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 6; c0 = c0 + 1) begin
          t0 = $signed(rom1_c[a1]);
          t1 = t0;
          r153[a0] = t1[6:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 144;
      end
      144: begin  // instr 107 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r153[a1]);
              r154[a0] = t0[6:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 6;
          end
        end
        state <= 145;
      end
      145: begin  // instr 108 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 80; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r154[a1]);
              t1 = $signed(r152[a2]);
              t2 = t0 + t1;
              r155[a0] = t2[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 6;
          end
          a2 = a2 - 480;
        end
        state <= 146;
      end
      146: begin  // instr 109 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom11_lit[a1]);
        t1 = t0;
        r156[a0] = t1[9:0];
        state <= 147;
      end
      147: begin  // instr 110 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 80; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r156[a1]);
              t1 = $signed(r155[a2]);
              t2 = (t0 < t1) ? t1 : t0;
              r157[a0] = t2[9:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a2 = a2 - 480;
        end
        state <= 148;
      end
      148: begin  // instr 111 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom12_lit[a1]);
        t1 = t0;
        r158[a0] = t1[9:0];
        state <= 149;
      end
      149: begin  // instr 112 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 80; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r158[a1]);
              t1 = $signed(r157[a2]);
              t2 = (t1 < t0) ? t1 : t0;
              r159[a0] = t2[9:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a2 = a2 - 480;
        end
        state <= 150;
      end
      150: begin  // instr 113 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r153[a1]);
              r160[a0] = t0[6:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 6;
          end
        end
        state <= 151;
      end
      151: begin  // instr 114 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 80; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r160[a1]);
              t1 = $signed(r152[a2]);
              t2 = t0 - t1;
              r161[a0] = t2[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 6;
          end
          a2 = a2 - 480;
        end
        state <= 152;
      end
      152: begin  // instr 115 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom11_lit[a1]);
        t1 = t0;
        r162[a0] = t1[9:0];
        state <= 153;
      end
      153: begin  // instr 116 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 80; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r162[a1]);
              t1 = $signed(r161[a2]);
              t2 = (t0 < t1) ? t1 : t0;
              r163[a0] = t2[9:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a2 = a2 - 480;
        end
        state <= 154;
      end
      154: begin  // instr 117 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom12_lit[a1]);
        t1 = t0;
        r164[a0] = t1[9:0];
        state <= 155;
      end
      155: begin  // instr 118 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 80; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r164[a1]);
              t1 = $signed(r163[a2]);
              t2 = (t1 < t0) ? t1 : t0;
              r165[a0] = t2[9:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a2 = a2 - 480;
        end
        state <= 156;
      end
      156: begin  // instr 119 abs
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 80; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r159[a1]);
              t1 = (t0 < 0) ? (0 - t0) : t0;
              r166[a0] = t1[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 480;
        end
        state <= 157;
      end
      157: begin  // instr 120 reduce_max
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 80; c0 = c0 + 1) begin
          r167[a0] = t0[9:0];
          a0 = a0 + 1;
        end
        state <= 158;
      end
      158: begin  // reduce.max.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 80; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r167[a0]);
              t1 = $signed(r166[a1]);
              t2 = (t0 < t1) ? t1 : t0;
              r167[a0] = t2[9:0];
              a1 = a1 + 1;
            end
            a0 = a0 + 1;
          end
        end
        state <= 159;
      end
      159: begin  // instr 121 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 80; c1 = c1 + 1) begin
            t0 = $signed(r167[a1]);
            t1 = $signed(rom13_lit[a2]);
            t2 = t0 - t1;
            r168[a0] = t2[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 80;
        end
        state <= 160;
      end
      160: begin  // instr 122 loop
        k2 = 0;
        state <= 161;
      end
      161: begin  // loop2.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 480; c0 = c0 + 1) begin
          t0 = $signed(r159[a1]);
          r169[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 162;
      end
      162: begin  // loop2.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom13_lit[a1]);
          r170[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 163;
      end
      163: begin  // loop2.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom9_lit[a1]);
          r171[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 164;
      end
      164: begin  // loop2.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 80; c0 = c0 + 1) begin
          t0 = $signed(r168[a1]);
          r172[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 165;
      end
      165: begin  // loop2.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 80; c0 = c0 + 1) begin
          t0 = $signed(r167[a1]);
          r173[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 166;
      end
      166: begin  // loop2.head
        if (k2 == 12) state <= 189;
        else state <= 167;
      end
      167: begin  // instr 123 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r171[a1]);
        t1 = $signed(rom8_lit[a2]);
        t2 = t0 + t1;
        r174[a0] = t2[4:0];
        state <= 168;
      end
      168: begin  // instr 124 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 80; c1 = c1 + 1) begin
            t0 = $signed(r172[a1]);
            t1 = $signed(r173[a2]);
            t2 = t0 + t1;
            r175[a0] = t2[10:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 - 80;
          a2 = a2 - 80;
        end
        state <= 169;
      end
      169: begin  // instr 125 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 80; c1 = c1 + 1) begin
            t0 = $signed(r175[a1]);
            t1 = t0 >>> 1;
            r176[a0] = t1[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 80;
        end
        state <= 170;
      end
      170: begin  // instr 126 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 80; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r176[a1]);
              r177[a0] = t0[9:0];
              a0 = a0 + 1;
            end
            a1 = a1 + 1;
          end
          a1 = a1 - 80;
        end
        state <= 171;
      end
      171: begin  // instr 127 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 80; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r169[a1]);
              t1 = $signed(r177[a2]);
              t2 = t0 - t1;
              r178[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a2 = a2 + 1;
          end
          a1 = a1 - 480;
          a2 = a2 - 80;
        end
        state <= 172;
      end
      172: begin  // instr 128 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 80; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r178[a1]);
              t1 = $signed(rom9_lit[a2]);
              t2 = (t0 < t1) ? t1 : t0;
              r179[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 480;
        end
        state <= 173;
      end
      173: begin  // instr 129 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 80; c0 = c0 + 1) begin
          r180[a0] = t0[13:0];
          a0 = a0 + 1;
        end
        state <= 174;
      end
      174: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 80; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r180[a0]);
              t1 = $signed(r179[a1]);
              t2 = t0 + t1;
              r180[a0] = t2[13:0];
              a1 = a1 + 1;
            end
            a0 = a0 + 1;
          end
        end
        state <= 175;
      end
      175: begin  // instr 130 neg
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 80; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r169[a1]);
              t1 = 0 - t0;
              r181[a0] = t1[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 480;
        end
        state <= 176;
      end
      176: begin  // instr 131 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 80; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r176[a1]);
              r182[a0] = t0[9:0];
              a0 = a0 + 1;
            end
            a1 = a1 + 1;
          end
          a1 = a1 - 80;
        end
        state <= 177;
      end
      177: begin  // instr 132 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 80; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r181[a1]);
              t1 = $signed(r182[a2]);
              t2 = t0 - t1;
              r183[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a2 = a2 + 1;
          end
          a1 = a1 - 480;
          a2 = a2 - 80;
        end
        state <= 178;
      end
      178: begin  // instr 133 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 80; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r183[a1]);
              t1 = $signed(rom9_lit[a2]);
              t2 = (t0 < t1) ? t1 : t0;
              r184[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 480;
        end
        state <= 179;
      end
      179: begin  // instr 134 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 80; c0 = c0 + 1) begin
          r185[a0] = t0[13:0];
          a0 = a0 + 1;
        end
        state <= 180;
      end
      180: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 80; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r185[a0]);
              t1 = $signed(r184[a1]);
              t2 = t0 + t1;
              r185[a0] = t2[13:0];
              a1 = a1 + 1;
            end
            a0 = a0 + 1;
          end
        end
        state <= 181;
      end
      181: begin  // instr 135 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 80; c1 = c1 + 1) begin
            t0 = $signed(r180[a1]);
            t1 = $signed(r185[a2]);
            t2 = t0 + t1;
            r186[a0] = t2[14:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 - 80;
          a2 = a2 - 80;
        end
        state <= 182;
      end
      182: begin  // instr 136 gt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 80; c1 = c1 + 1) begin
            t0 = $signed(r186[a1]);
            t1 = $signed(r170[a2]);
            t2 = (t0 > t1) ? 1 : 0;
            r187[a0] = (t2 != 0);
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 80;
        end
        state <= 183;
      end
      183: begin  // instr 137 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 80; c1 = c1 + 1) begin
            t0 = r187[a1];
            t1 = $signed(r172[a2]);
            t2 = $signed(r176[a3]);
            t3 = (t0 != 0) ? t2 : t1;
            r188[a0] = t3[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
            a3 = a3 + 1;
          end
          a1 = a1 - 80;
          a2 = a2 - 80;
          a3 = a3 - 80;
        end
        state <= 184;
      end
      184: begin  // instr 138 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 80; c1 = c1 + 1) begin
            t0 = r187[a1];
            t1 = $signed(r176[a2]);
            t2 = $signed(r173[a3]);
            t3 = (t0 != 0) ? t2 : t1;
            r189[a0] = t3[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
            a3 = a3 + 1;
          end
          a1 = a1 - 80;
          a2 = a2 - 80;
          a3 = a3 - 80;
        end
        state <= 185;
      end
      185: begin  // loop2.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r174[a1]);
          r171[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 186;
      end
      186: begin  // loop2.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 80; c0 = c0 + 1) begin
          t0 = $signed(r188[a1]);
          r172[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 187;
      end
      187: begin  // loop2.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 80; c0 = c0 + 1) begin
          t0 = $signed(r189[a1]);
          r173[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 188;
      end
      188: begin  // loop2.adv
        k2 = k2 + 1;
        state <= 166;
      end
      189: begin  // loop2.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r171[a1]);
          r190[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 190;
      end
      190: begin  // loop2.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 80; c0 = c0 + 1) begin
          t0 = $signed(r172[a1]);
          r191[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 191;
      end
      191: begin  // loop2.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 80; c0 = c0 + 1) begin
          t0 = $signed(r173[a1]);
          r192[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 192;
      end
      192: begin  // instr 139 abs
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 80; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r165[a1]);
              t1 = (t0 < 0) ? (0 - t0) : t0;
              r193[a0] = t1[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 480;
        end
        state <= 193;
      end
      193: begin  // instr 140 reduce_max
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 80; c0 = c0 + 1) begin
          r194[a0] = t0[9:0];
          a0 = a0 + 1;
        end
        state <= 194;
      end
      194: begin  // reduce.max.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 80; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r194[a0]);
              t1 = $signed(r193[a1]);
              t2 = (t0 < t1) ? t1 : t0;
              r194[a0] = t2[9:0];
              a1 = a1 + 1;
            end
            a0 = a0 + 1;
          end
        end
        state <= 195;
      end
      195: begin  // instr 141 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 80; c1 = c1 + 1) begin
            t0 = $signed(r194[a1]);
            t1 = $signed(rom13_lit[a2]);
            t2 = t0 - t1;
            r195[a0] = t2[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 80;
        end
        state <= 196;
      end
      196: begin  // instr 142 loop
        k3 = 0;
        state <= 197;
      end
      197: begin  // loop3.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 480; c0 = c0 + 1) begin
          t0 = $signed(r165[a1]);
          r196[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 198;
      end
      198: begin  // loop3.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom13_lit[a1]);
          r197[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 199;
      end
      199: begin  // loop3.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom9_lit[a1]);
          r198[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 200;
      end
      200: begin  // loop3.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 80; c0 = c0 + 1) begin
          t0 = $signed(r195[a1]);
          r199[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 201;
      end
      201: begin  // loop3.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 80; c0 = c0 + 1) begin
          t0 = $signed(r194[a1]);
          r200[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 202;
      end
      202: begin  // loop3.head
        if (k3 == 12) state <= 225;
        else state <= 203;
      end
      203: begin  // instr 143 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r198[a1]);
        t1 = $signed(rom8_lit[a2]);
        t2 = t0 + t1;
        r201[a0] = t2[4:0];
        state <= 204;
      end
      204: begin  // instr 144 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 80; c1 = c1 + 1) begin
            t0 = $signed(r199[a1]);
            t1 = $signed(r200[a2]);
            t2 = t0 + t1;
            r202[a0] = t2[10:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 - 80;
          a2 = a2 - 80;
        end
        state <= 205;
      end
      205: begin  // instr 145 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 80; c1 = c1 + 1) begin
            t0 = $signed(r202[a1]);
            t1 = t0 >>> 1;
            r203[a0] = t1[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 80;
        end
        state <= 206;
      end
      206: begin  // instr 146 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 80; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r203[a1]);
              r204[a0] = t0[9:0];
              a0 = a0 + 1;
            end
            a1 = a1 + 1;
          end
          a1 = a1 - 80;
        end
        state <= 207;
      end
      207: begin  // instr 147 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 80; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r196[a1]);
              t1 = $signed(r204[a2]);
              t2 = t0 - t1;
              r205[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a2 = a2 + 1;
          end
          a1 = a1 - 480;
          a2 = a2 - 80;
        end
        state <= 208;
      end
      208: begin  // instr 148 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 80; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r205[a1]);
              t1 = $signed(rom9_lit[a2]);
              t2 = (t0 < t1) ? t1 : t0;
              r206[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 480;
        end
        state <= 209;
      end
      209: begin  // instr 149 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 80; c0 = c0 + 1) begin
          r207[a0] = t0[13:0];
          a0 = a0 + 1;
        end
        state <= 210;
      end
      210: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 80; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r207[a0]);
              t1 = $signed(r206[a1]);
              t2 = t0 + t1;
              r207[a0] = t2[13:0];
              a1 = a1 + 1;
            end
            a0 = a0 + 1;
          end
        end
        state <= 211;
      end
      211: begin  // instr 150 neg
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 80; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r196[a1]);
              t1 = 0 - t0;
              r208[a0] = t1[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 480;
        end
        state <= 212;
      end
      212: begin  // instr 151 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 80; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r203[a1]);
              r209[a0] = t0[9:0];
              a0 = a0 + 1;
            end
            a1 = a1 + 1;
          end
          a1 = a1 - 80;
        end
        state <= 213;
      end
      213: begin  // instr 152 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 80; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r208[a1]);
              t1 = $signed(r209[a2]);
              t2 = t0 - t1;
              r210[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a2 = a2 + 1;
          end
          a1 = a1 - 480;
          a2 = a2 - 80;
        end
        state <= 214;
      end
      214: begin  // instr 153 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 80; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r210[a1]);
              t1 = $signed(rom9_lit[a2]);
              t2 = (t0 < t1) ? t1 : t0;
              r211[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 480;
        end
        state <= 215;
      end
      215: begin  // instr 154 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 80; c0 = c0 + 1) begin
          r212[a0] = t0[13:0];
          a0 = a0 + 1;
        end
        state <= 216;
      end
      216: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 80; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r212[a0]);
              t1 = $signed(r211[a1]);
              t2 = t0 + t1;
              r212[a0] = t2[13:0];
              a1 = a1 + 1;
            end
            a0 = a0 + 1;
          end
        end
        state <= 217;
      end
      217: begin  // instr 155 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 80; c1 = c1 + 1) begin
            t0 = $signed(r207[a1]);
            t1 = $signed(r212[a2]);
            t2 = t0 + t1;
            r213[a0] = t2[14:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 - 80;
          a2 = a2 - 80;
        end
        state <= 218;
      end
      218: begin  // instr 156 gt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 80; c1 = c1 + 1) begin
            t0 = $signed(r213[a1]);
            t1 = $signed(r197[a2]);
            t2 = (t0 > t1) ? 1 : 0;
            r214[a0] = (t2 != 0);
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 80;
        end
        state <= 219;
      end
      219: begin  // instr 157 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 80; c1 = c1 + 1) begin
            t0 = r214[a1];
            t1 = $signed(r199[a2]);
            t2 = $signed(r203[a3]);
            t3 = (t0 != 0) ? t2 : t1;
            r215[a0] = t3[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
            a3 = a3 + 1;
          end
          a1 = a1 - 80;
          a2 = a2 - 80;
          a3 = a3 - 80;
        end
        state <= 220;
      end
      220: begin  // instr 158 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 80; c1 = c1 + 1) begin
            t0 = r214[a1];
            t1 = $signed(r203[a2]);
            t2 = $signed(r200[a3]);
            t3 = (t0 != 0) ? t2 : t1;
            r216[a0] = t3[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
            a3 = a3 + 1;
          end
          a1 = a1 - 80;
          a2 = a2 - 80;
          a3 = a3 - 80;
        end
        state <= 221;
      end
      221: begin  // loop3.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r201[a1]);
          r198[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 222;
      end
      222: begin  // loop3.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 80; c0 = c0 + 1) begin
          t0 = $signed(r215[a1]);
          r199[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 223;
      end
      223: begin  // loop3.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 80; c0 = c0 + 1) begin
          t0 = $signed(r216[a1]);
          r200[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 224;
      end
      224: begin  // loop3.adv
        k3 = k3 + 1;
        state <= 202;
      end
      225: begin  // loop3.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r198[a1]);
          r217[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 226;
      end
      226: begin  // loop3.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 80; c0 = c0 + 1) begin
          t0 = $signed(r199[a1]);
          r218[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 227;
      end
      227: begin  // loop3.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 80; c0 = c0 + 1) begin
          t0 = $signed(r200[a1]);
          r219[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 228;
      end
      228: begin  // instr 159 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 80; c1 = c1 + 1) begin
            t0 = $signed(r192[a1]);
            t1 = $signed(r219[a2]);
            t2 = t0 - t1;
            r220[a0] = t2[10:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 - 80;
          a2 = a2 - 80;
        end
        state <= 229;
      end
      229: begin  // instr 160 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 80; c1 = c1 + 1) begin
            t0 = $signed(r220[a1]);
            t1 = t0 >>> 1;
            r221[a0] = t1[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 80;
        end
        state <= 230;
      end
      230: begin  // instr 161 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom15_lit[a1]);
        t1 = t0;
        r224[a0] = t1[7:0];
        state <= 231;
      end
      231: begin  // instr 162 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 80; c1 = c1 + 1) begin
            t0 = $signed(r224[a1]);
            t1 = $signed(r221[a2]);
            t2 = (t0 < t1) ? t1 : t0;
            r225[a0] = t2[9:0];
            a0 = a0 + 1;
            a2 = a2 + 1;
          end
          a2 = a2 - 80;
        end
        state <= 232;
      end
      232: begin  // instr 163 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom16_lit[a1]);
        t1 = t0;
        r226[a0] = t1[7:0];
        state <= 233;
      end
      233: begin  // instr 164 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 80; c1 = c1 + 1) begin
            t0 = $signed(r226[a1]);
            t1 = $signed(r225[a2]);
            t2 = (t1 < t0) ? t1 : t0;
            r227[a0] = t2[7:0];
            a0 = a0 + 1;
            a2 = a2 + 1;
          end
          a2 = a2 - 80;
        end
        state <= 234;
      end
      234: begin  // instr 165 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r17[a1]);
          t1 = $signed(r133[a2]);
          t2 = t0 - t1;
          r228[a0] = t2[8:0];
          a0 = a0 + 1;
        end
        state <= 235;
      end
      235: begin  // instr 166 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r228[a1]);
          t1 = $signed(rom8_lit[a2]);
          t2 = t0 + t1;
          r229[a0] = t2[8:0];
          a0 = a0 + 1;
        end
        state <= 236;
      end
      236: begin  // instr 167 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r229[a1]);
          t1 = $signed(rom9_lit[a2]);
          t2 = (t0 < t1) ? t1 : t0;
          r230[a0] = t2[8:0];
          a0 = a0 + 1;
        end
        state <= 237;
      end
      237: begin  // instr 168 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r230[a1]);
          t1 = t0 >>> 1;
          r231[a0] = t1[7:0];
          a0 = a0 + 1;
        end
        state <= 238;
      end
      238: begin  // instr 169 concat
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 15; c1 = c1 + 1) begin
            t0 = $signed(r1[a1]);
            r232[a0] = t0[7:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a0 = a0 + 80;
        end
        state <= 239;
      end
      239: begin  // concat
        a0 = 15;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 80; c1 = c1 + 1) begin
            t0 = $signed(r227[a1]);
            r232[a0] = t0[7:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a0 = a0 + 15;
        end
        state <= 240;
      end
      240: begin  // instr 170 shl
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 95; c1 = c1 + 1) begin
            t0 = $signed(r232[a1]);
            t1 = t0 << 1;
            r233[a0] = t1[8:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 95;
        end
        state <= 241;
      end
      241: begin  // instr 171 mov
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(rom0_c[a1]);
            t1 = t0;
            r234[a0] = t1[5:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
        end
        state <= 242;
      end
      242: begin  // instr 172 rev
        a0 = 0;
        a1 = 15;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(r234[a1]);
            r235[a0] = t0[5:0];
            a0 = a0 + 1;
            a1 = a1 - 1;
          end
          a1 = a1 + 32;
        end
        state <= 243;
      end
      243: begin  // instr 173 reshape
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 80; c0 = c0 + 1) begin
          t0 = $signed(r235[a1]);
          r236[a0] = t0[5:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 244;
      end
      244: begin  // instr 174 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 80; c0 = c0 + 1) begin
          t0 = a1;
          r237[a0] = t0[7:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 245;
      end
      245: begin  // instr 175 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 80; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            t0 = $signed(r237[a1]);
            r238[a0] = t0[7:0];
            a0 = a0 + 1;
          end
          a1 = a1 + 1;
        end
        state <= 246;
      end
      246: begin  // instr 176 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 16; c0 = c0 + 1) begin
          t0 = a1;
          r239[a0] = t0[4:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 247;
      end
      247: begin  // instr 177 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(r239[a1]);
            r240[a0] = t0[4:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 16;
        end
        state <= 248;
      end
      248: begin  // instr 178 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 80; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(r238[a1]);
            t1 = $signed(r240[a2]);
            t2 = t0 + t1;
            r241[a0] = t2[7:0];
            a0 = a0 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 + 1;
          a2 = a2 - 16;
        end
        state <= 249;
      end
      249: begin  // instr 179 lt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 80; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(r241[a1]);
            t1 = $signed(rom9_lit[a2]);
            t2 = (t0 < t1) ? 1 : 0;
            r242[a0] = (t2 != 0);
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
        end
        state <= 250;
      end
      250: begin  // instr 180 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 80; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(r241[a1]);
            t1 = $signed(rom17_lit[a2]);
            t2 = t0 + t1;
            r244[a0] = t2[8:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
        end
        state <= 251;
      end
      251: begin  // instr 181 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 80; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = r242[a1];
            t1 = $signed(r241[a2]);
            t2 = $signed(r244[a3]);
            t3 = (t0 != 0) ? t2 : t1;
            r245[a0] = t3[7:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
            a3 = a3 + 1;
          end
        end
        state <= 252;
      end
      252: begin  // instr 182 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 80; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r245[a1]);
              r246[a0] = t0[7:0];
              a0 = a0 + 1;
            end
            a1 = a1 + 1;
          end
        end
        state <= 253;
      end
      253: begin  // instr 183 gather
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 80; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 16; c2 = c2 + 1) begin
              t9 = 0;
              t0 = $signed(r246[a2]);
              t1 = (t0 < 0) ? 0 : t0;
              t1 = (t1 > 94) ? 94 : t1;
              t2 = t1;
              t9 = t9 + t2;
              t3 = $signed(r233[a1 + t9]);
              r247[a0] = t3[8:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a1 = a1 + 95;
          a2 = a2 - 1280;
        end
        state <= 254;
      end
      254: begin  // instr 184 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 80; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r247[a1]);
                r248[a0] = t0[8:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 1280;
          end
        end
        state <= 255;
      end
      255: begin  // instr 185 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 80; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r236[a1]);
                t1 = $signed(r248[a2]);
                t2 = t0 + t1;
                r249[a0] = t2[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
                a2 = a2 + 1;
              end
              a1 = a1 - 16;
            end
            a2 = a2 - 1280;
          end
          a1 = a1 + 16;
        end
        state <= 256;
      end
      256: begin  // instr 186 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom11_lit[a1]);
        t1 = t0;
        r250[a0] = t1[9:0];
        state <= 257;
      end
      257: begin  // instr 187 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 80; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r250[a1]);
                t1 = $signed(r249[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r251[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 1280;
          end
          a2 = a2 + 1280;
        end
        state <= 258;
      end
      258: begin  // instr 188 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom12_lit[a1]);
        t1 = t0;
        r252[a0] = t1[9:0];
        state <= 259;
      end
      259: begin  // instr 189 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 80; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r252[a1]);
                t1 = $signed(r251[a2]);
                t2 = (t1 < t0) ? t1 : t0;
                r253[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 1280;
          end
          a2 = a2 + 1280;
        end
        state <= 260;
      end
      260: begin  // instr 190 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 80; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r236[a1]);
                t1 = $signed(r248[a2]);
                t2 = t0 - t1;
                r254[a0] = t2[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
                a2 = a2 + 1;
              end
              a1 = a1 - 16;
            end
            a2 = a2 - 1280;
          end
          a1 = a1 + 16;
        end
        state <= 261;
      end
      261: begin  // instr 191 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom11_lit[a1]);
        t1 = t0;
        r255[a0] = t1[9:0];
        state <= 262;
      end
      262: begin  // instr 192 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 80; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r255[a1]);
                t1 = $signed(r254[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r256[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 1280;
          end
          a2 = a2 + 1280;
        end
        state <= 263;
      end
      263: begin  // instr 193 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom12_lit[a1]);
        t1 = t0;
        r257[a0] = t1[9:0];
        state <= 264;
      end
      264: begin  // instr 194 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 80; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r257[a1]);
                t1 = $signed(r256[a2]);
                t2 = (t1 < t0) ? t1 : t0;
                r258[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 1280;
          end
          a2 = a2 + 1280;
        end
        state <= 265;
      end
      265: begin  // instr 195 abs
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 80; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r253[a1]);
                t1 = (t0 < 0) ? (0 - t0) : t0;
                r259[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 1280;
          end
          a1 = a1 + 1280;
        end
        state <= 266;
      end
      266: begin  // instr 196 reduce_max
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 400; c0 = c0 + 1) begin
          r260[a0] = t0[9:0];
          a0 = a0 + 1;
        end
        state <= 267;
      end
      267: begin  // reduce.max.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 80; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r260[a0]);
                t1 = $signed(r259[a1]);
                t2 = (t0 < t1) ? t1 : t0;
                r260[a0] = t2[9:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 268;
      end
      268: begin  // instr 197 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 80; c2 = c2 + 1) begin
              t0 = $signed(r260[a1]);
              t1 = $signed(rom13_lit[a2]);
              t2 = t0 - t1;
              r261[a0] = t2[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 80;
          end
          a1 = a1 + 80;
        end
        state <= 269;
      end
      269: begin  // instr 198 loop
        k4 = 0;
        state <= 270;
      end
      270: begin  // loop4.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 6400; c0 = c0 + 1) begin
          t0 = $signed(r253[a1]);
          r262[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 271;
      end
      271: begin  // loop4.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom13_lit[a1]);
          r263[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 272;
      end
      272: begin  // loop4.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom9_lit[a1]);
          r264[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 273;
      end
      273: begin  // loop4.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 400; c0 = c0 + 1) begin
          t0 = $signed(r261[a1]);
          r265[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 274;
      end
      274: begin  // loop4.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 400; c0 = c0 + 1) begin
          t0 = $signed(r260[a1]);
          r266[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 275;
      end
      275: begin  // loop4.head
        if (k4 == 12) state <= 298;
        else state <= 276;
      end
      276: begin  // instr 199 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r264[a1]);
        t1 = $signed(rom8_lit[a2]);
        t2 = t0 + t1;
        r267[a0] = t2[4:0];
        state <= 277;
      end
      277: begin  // instr 200 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 80; c2 = c2 + 1) begin
              t0 = $signed(r265[a1]);
              t1 = $signed(r266[a2]);
              t2 = t0 + t1;
              r268[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 80;
            a2 = a2 - 80;
          end
          a1 = a1 + 80;
          a2 = a2 + 80;
        end
        state <= 278;
      end
      278: begin  // instr 201 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 80; c2 = c2 + 1) begin
              t0 = $signed(r268[a1]);
              t1 = t0 >>> 1;
              r269[a0] = t1[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 80;
          end
          a1 = a1 + 80;
        end
        state <= 279;
      end
      279: begin  // instr 202 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 80; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r269[a1]);
                r270[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 80;
          end
          a1 = a1 + 80;
        end
        state <= 280;
      end
      280: begin  // instr 203 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 80; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r262[a1]);
                t1 = $signed(r270[a2]);
                t2 = t0 - t1;
                r271[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 1280;
            a2 = a2 - 80;
          end
          a1 = a1 + 1280;
          a2 = a2 + 80;
        end
        state <= 281;
      end
      281: begin  // instr 204 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 80; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r271[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r272[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 1280;
          end
          a1 = a1 + 1280;
        end
        state <= 282;
      end
      282: begin  // instr 205 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 400; c0 = c0 + 1) begin
          r273[a0] = t0[14:0];
          a0 = a0 + 1;
        end
        state <= 283;
      end
      283: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 80; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r273[a0]);
                t1 = $signed(r272[a1]);
                t2 = t0 + t1;
                r273[a0] = t2[14:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 284;
      end
      284: begin  // instr 206 neg
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 80; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r262[a1]);
                t1 = 0 - t0;
                r274[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 1280;
          end
          a1 = a1 + 1280;
        end
        state <= 285;
      end
      285: begin  // instr 207 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 80; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r269[a1]);
                r275[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 80;
          end
          a1 = a1 + 80;
        end
        state <= 286;
      end
      286: begin  // instr 208 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 80; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r274[a1]);
                t1 = $signed(r275[a2]);
                t2 = t0 - t1;
                r276[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 1280;
            a2 = a2 - 80;
          end
          a1 = a1 + 1280;
          a2 = a2 + 80;
        end
        state <= 287;
      end
      287: begin  // instr 209 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 80; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r276[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r277[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 1280;
          end
          a1 = a1 + 1280;
        end
        state <= 288;
      end
      288: begin  // instr 210 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 400; c0 = c0 + 1) begin
          r278[a0] = t0[14:0];
          a0 = a0 + 1;
        end
        state <= 289;
      end
      289: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 80; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r278[a0]);
                t1 = $signed(r277[a1]);
                t2 = t0 + t1;
                r278[a0] = t2[14:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 290;
      end
      290: begin  // instr 211 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 80; c2 = c2 + 1) begin
              t0 = $signed(r273[a1]);
              t1 = $signed(r278[a2]);
              t2 = t0 + t1;
              r279[a0] = t2[15:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 80;
            a2 = a2 - 80;
          end
          a1 = a1 + 80;
          a2 = a2 + 80;
        end
        state <= 291;
      end
      291: begin  // instr 212 gt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 80; c2 = c2 + 1) begin
              t0 = $signed(r279[a1]);
              t1 = $signed(r263[a2]);
              t2 = (t0 > t1) ? 1 : 0;
              r280[a0] = (t2 != 0);
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 80;
          end
          a1 = a1 + 80;
        end
        state <= 292;
      end
      292: begin  // instr 213 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 80; c2 = c2 + 1) begin
              t0 = r280[a1];
              t1 = $signed(r265[a2]);
              t2 = $signed(r269[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r281[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 80;
            a2 = a2 - 80;
            a3 = a3 - 80;
          end
          a1 = a1 + 80;
          a2 = a2 + 80;
          a3 = a3 + 80;
        end
        state <= 293;
      end
      293: begin  // instr 214 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 80; c2 = c2 + 1) begin
              t0 = r280[a1];
              t1 = $signed(r269[a2]);
              t2 = $signed(r266[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r282[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 80;
            a2 = a2 - 80;
            a3 = a3 - 80;
          end
          a1 = a1 + 80;
          a2 = a2 + 80;
          a3 = a3 + 80;
        end
        state <= 294;
      end
      294: begin  // loop4.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r267[a1]);
          r264[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 295;
      end
      295: begin  // loop4.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 400; c0 = c0 + 1) begin
          t0 = $signed(r281[a1]);
          r265[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 296;
      end
      296: begin  // loop4.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 400; c0 = c0 + 1) begin
          t0 = $signed(r282[a1]);
          r266[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 297;
      end
      297: begin  // loop4.adv
        k4 = k4 + 1;
        state <= 275;
      end
      298: begin  // loop4.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r264[a1]);
          r283[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 299;
      end
      299: begin  // loop4.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 400; c0 = c0 + 1) begin
          t0 = $signed(r265[a1]);
          r284[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 300;
      end
      300: begin  // loop4.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 400; c0 = c0 + 1) begin
          t0 = $signed(r266[a1]);
          r285[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 301;
      end
      301: begin  // instr 215 abs
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 80; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r258[a1]);
                t1 = (t0 < 0) ? (0 - t0) : t0;
                r286[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 1280;
          end
          a1 = a1 + 1280;
        end
        state <= 302;
      end
      302: begin  // instr 216 reduce_max
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 400; c0 = c0 + 1) begin
          r287[a0] = t0[9:0];
          a0 = a0 + 1;
        end
        state <= 303;
      end
      303: begin  // reduce.max.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 80; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r287[a0]);
                t1 = $signed(r286[a1]);
                t2 = (t0 < t1) ? t1 : t0;
                r287[a0] = t2[9:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 304;
      end
      304: begin  // instr 217 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 80; c2 = c2 + 1) begin
              t0 = $signed(r287[a1]);
              t1 = $signed(rom13_lit[a2]);
              t2 = t0 - t1;
              r288[a0] = t2[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 80;
          end
          a1 = a1 + 80;
        end
        state <= 305;
      end
      305: begin  // instr 218 loop
        k5 = 0;
        state <= 306;
      end
      306: begin  // loop5.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 6400; c0 = c0 + 1) begin
          t0 = $signed(r258[a1]);
          r289[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 307;
      end
      307: begin  // loop5.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom13_lit[a1]);
          r290[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 308;
      end
      308: begin  // loop5.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom9_lit[a1]);
          r291[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 309;
      end
      309: begin  // loop5.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 400; c0 = c0 + 1) begin
          t0 = $signed(r288[a1]);
          r292[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 310;
      end
      310: begin  // loop5.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 400; c0 = c0 + 1) begin
          t0 = $signed(r287[a1]);
          r293[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 311;
      end
      311: begin  // loop5.head
        if (k5 == 12) state <= 334;
        else state <= 312;
      end
      312: begin  // instr 219 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r291[a1]);
        t1 = $signed(rom8_lit[a2]);
        t2 = t0 + t1;
        r294[a0] = t2[4:0];
        state <= 313;
      end
      313: begin  // instr 220 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 80; c2 = c2 + 1) begin
              t0 = $signed(r292[a1]);
              t1 = $signed(r293[a2]);
              t2 = t0 + t1;
              r295[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 80;
            a2 = a2 - 80;
          end
          a1 = a1 + 80;
          a2 = a2 + 80;
        end
        state <= 314;
      end
      314: begin  // instr 221 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 80; c2 = c2 + 1) begin
              t0 = $signed(r295[a1]);
              t1 = t0 >>> 1;
              r296[a0] = t1[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 80;
          end
          a1 = a1 + 80;
        end
        state <= 315;
      end
      315: begin  // instr 222 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 80; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r296[a1]);
                r297[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 80;
          end
          a1 = a1 + 80;
        end
        state <= 316;
      end
      316: begin  // instr 223 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 80; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r289[a1]);
                t1 = $signed(r297[a2]);
                t2 = t0 - t1;
                r298[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 1280;
            a2 = a2 - 80;
          end
          a1 = a1 + 1280;
          a2 = a2 + 80;
        end
        state <= 317;
      end
      317: begin  // instr 224 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 80; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r298[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r299[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 1280;
          end
          a1 = a1 + 1280;
        end
        state <= 318;
      end
      318: begin  // instr 225 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 400; c0 = c0 + 1) begin
          r300[a0] = t0[14:0];
          a0 = a0 + 1;
        end
        state <= 319;
      end
      319: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 80; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r300[a0]);
                t1 = $signed(r299[a1]);
                t2 = t0 + t1;
                r300[a0] = t2[14:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 320;
      end
      320: begin  // instr 226 neg
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 80; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r289[a1]);
                t1 = 0 - t0;
                r301[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 1280;
          end
          a1 = a1 + 1280;
        end
        state <= 321;
      end
      321: begin  // instr 227 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 80; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r296[a1]);
                r302[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 80;
          end
          a1 = a1 + 80;
        end
        state <= 322;
      end
      322: begin  // instr 228 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 80; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r301[a1]);
                t1 = $signed(r302[a2]);
                t2 = t0 - t1;
                r303[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 1280;
            a2 = a2 - 80;
          end
          a1 = a1 + 1280;
          a2 = a2 + 80;
        end
        state <= 323;
      end
      323: begin  // instr 229 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 80; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r303[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r304[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 1280;
          end
          a1 = a1 + 1280;
        end
        state <= 324;
      end
      324: begin  // instr 230 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 400; c0 = c0 + 1) begin
          r305[a0] = t0[14:0];
          a0 = a0 + 1;
        end
        state <= 325;
      end
      325: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 80; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r305[a0]);
                t1 = $signed(r304[a1]);
                t2 = t0 + t1;
                r305[a0] = t2[14:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 326;
      end
      326: begin  // instr 231 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 80; c2 = c2 + 1) begin
              t0 = $signed(r300[a1]);
              t1 = $signed(r305[a2]);
              t2 = t0 + t1;
              r306[a0] = t2[15:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 80;
            a2 = a2 - 80;
          end
          a1 = a1 + 80;
          a2 = a2 + 80;
        end
        state <= 327;
      end
      327: begin  // instr 232 gt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 80; c2 = c2 + 1) begin
              t0 = $signed(r306[a1]);
              t1 = $signed(r290[a2]);
              t2 = (t0 > t1) ? 1 : 0;
              r307[a0] = (t2 != 0);
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 80;
          end
          a1 = a1 + 80;
        end
        state <= 328;
      end
      328: begin  // instr 233 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 80; c2 = c2 + 1) begin
              t0 = r307[a1];
              t1 = $signed(r292[a2]);
              t2 = $signed(r296[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r308[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 80;
            a2 = a2 - 80;
            a3 = a3 - 80;
          end
          a1 = a1 + 80;
          a2 = a2 + 80;
          a3 = a3 + 80;
        end
        state <= 329;
      end
      329: begin  // instr 234 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 80; c2 = c2 + 1) begin
              t0 = r307[a1];
              t1 = $signed(r296[a2]);
              t2 = $signed(r293[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r309[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 80;
            a2 = a2 - 80;
            a3 = a3 - 80;
          end
          a1 = a1 + 80;
          a2 = a2 + 80;
          a3 = a3 + 80;
        end
        state <= 330;
      end
      330: begin  // loop5.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r294[a1]);
          r291[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 331;
      end
      331: begin  // loop5.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 400; c0 = c0 + 1) begin
          t0 = $signed(r308[a1]);
          r292[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 332;
      end
      332: begin  // loop5.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 400; c0 = c0 + 1) begin
          t0 = $signed(r309[a1]);
          r293[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 333;
      end
      333: begin  // loop5.adv
        k5 = k5 + 1;
        state <= 311;
      end
      334: begin  // loop5.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r291[a1]);
          r310[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 335;
      end
      335: begin  // loop5.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 400; c0 = c0 + 1) begin
          t0 = $signed(r292[a1]);
          r311[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 336;
      end
      336: begin  // loop5.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 400; c0 = c0 + 1) begin
          t0 = $signed(r293[a1]);
          r312[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 337;
      end
      337: begin  // instr 235 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 80; c2 = c2 + 1) begin
              t0 = $signed(r285[a1]);
              t1 = $signed(r312[a2]);
              t2 = t0 - t1;
              r313[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 80;
            a2 = a2 - 80;
          end
          a1 = a1 + 80;
          a2 = a2 + 80;
        end
        state <= 338;
      end
      338: begin  // instr 236 transpose
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 80; c2 = c2 + 1) begin
              t0 = $signed(r313[a1]);
              r314[a0] = t0[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 320;
        end
        state <= 339;
      end
      339: begin  // instr 237 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            t0 = $signed(r231[a1]);
            r315[a0] = t0[7:0];
            a0 = a0 + 1;
          end
        end
        state <= 340;
      end
      340: begin  // instr 238 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 80; c2 = c2 + 1) begin
              t0 = $signed(r314[a1]);
              t1 = $signed(rom9_lit[a2]);
              t2 = (t0 < t1) ? t1 : t0;
              r316[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 400;
        end
        state <= 341;
      end
      341: begin  // instr 239 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 80; c2 = c2 + 1) begin
              t0 = a1;
              r317[a0] = t0[7:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 80;
          end
        end
        state <= 342;
      end
      342: begin  // instr 240 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r315[a1]);
              r318[a0] = t0[7:0];
              a0 = a0 + 1;
            end
          end
        end
        state <= 343;
      end
      343: begin  // instr 241 lt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 80; c2 = c2 + 1) begin
              t0 = $signed(r317[a1]);
              t1 = $signed(r318[a2]);
              t2 = (t0 < t1) ? 1 : 0;
              r319[a0] = (t2 != 0);
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 400;
        end
        state <= 344;
      end
      344: begin  // instr 242 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom9_lit[a1]);
        t1 = t0;
        r320[a0] = t1[0:0];
        state <= 345;
      end
      345: begin  // instr 243 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 80; c2 = c2 + 1) begin
              t0 = $signed(r320[a1]);
              r321[a0] = t0[0:0];
              a0 = a0 + 1;
            end
          end
        end
        state <= 346;
      end
      346: begin  // instr 244 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 80; c2 = c2 + 1) begin
              t0 = r319[a1];
              t1 = $signed(r321[a2]);
              t2 = $signed(r316[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r322[a0] = t3[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
          end
          a1 = a1 - 400;
          a2 = a2 - 400;
          a3 = a3 - 400;
        end
        state <= 347;
      end
      347: begin  // instr 245 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          r323[a0] = t0[16:0];
          a0 = a0 + 1;
        end
        state <= 348;
      end
      348: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 80; c2 = c2 + 1) begin
              t0 = $signed(r323[a0]);
              t1 = $signed(r322[a1]);
              t2 = t0 + t1;
              r323[a0] = t2[16:0];
              a1 = a1 + 1;
            end
            a0 = a0 + 1;
          end
        end
        state <= 349;
      end
      349: begin  // instr 246 shl
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            t0 = $signed(r323[a1]);
            t1 = t0 << 1;
            r324[a0] = t1[17:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 5;
        end
        state <= 350;
      end
      350: begin  // instr 247 lt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r231[a1]);
          t1 = $signed(rom9_lit[a2]);
          t2 = (t0 < t1) ? 1 : 0;
          r325[a0] = (t2 != 0);
          a0 = a0 + 1;
        end
        state <= 351;
      end
      351: begin  // instr 248 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r231[a1]);
          t1 = $signed(rom17_lit[a2]);
          t2 = t0 + t1;
          r326[a0] = t2[8:0];
          a0 = a0 + 1;
        end
        state <= 352;
      end
      352: begin  // instr 249 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = r325[a1];
          t1 = $signed(r231[a2]);
          t2 = $signed(r326[a3]);
          t3 = (t0 != 0) ? t2 : t1;
          r327[a0] = t3[7:0];
          a0 = a0 + 1;
        end
        state <= 353;
      end
      353: begin  // instr 250 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            t0 = $signed(r327[a1]);
            r328[a0] = t0[7:0];
            a0 = a0 + 1;
          end
        end
        state <= 354;
      end
      354: begin  // instr 251 gather
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 15; c1 = c1 + 1) begin
            t9 = 0;
            t0 = $signed(r328[a2]);
            t1 = (t0 < 0) ? 0 : t0;
            t1 = (t1 > 80) ? 80 : t1;
            t2 = t1;
            t9 = t9 + t2;
            t3 = $signed(r232[a1 + t9]);
            r329[a0] = t3[7:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 + 80;
          a2 = a2 + 1;
        end
        state <= 355;
      end
      355: begin  // instr 252 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r7[a1]);
          t1 = $signed(r231[a2]);
          t2 = t0 + t1;
          r330[a0] = t2;
          a0 = a0 + 1;
        end
        state <= 356;
      end
      356: begin  // instr 253 and
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r7[a1]);
          t1 = $signed(rom8_lit[a2]);
          t2 = t0 & t1;
          r331[a0] = t2[1:0];
          a0 = a0 + 1;
        end
        state <= 357;
      end
      357: begin  // instr 254 slice
        a0 = 0;
        a1 = 10;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 85; c1 = c1 + 1) begin
            t0 = $signed(r232[a1]);
            r332[a0] = t0[7:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 + 10;
        end
        state <= 358;
      end
      358: begin  // instr 255 shl
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 85; c1 = c1 + 1) begin
            t0 = $signed(r332[a1]);
            t1 = t0 << 1;
            r333[a0] = t1[8:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 85;
        end
        state <= 359;
      end
      359: begin  // instr 256 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom9_lit[a1]);
        t1 = t0;
        r334[a0] = t1[0:0];
        state <= 360;
      end
      360: begin  // instr 257 pad
        t0 = $signed(r334[0]);
        a0 = 0;
        for (c0 = 0; c0 < 86; c0 = c0 + 1) begin
          r335[a0] = t0[8:0];
          a0 = a0 + 1;
        end
        state <= 361;
      end
      361: begin  // pad.scatter
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 85; c1 = c1 + 1) begin
            t1 = $signed(r333[a1]);
            r335[a0] = t1[8:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a0 = a0 + 1;
        end
        state <= 362;
      end
      362: begin  // instr 258 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 40; c0 = c0 + 1) begin
          t0 = a1;
          r336[a0] = t0[6:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 363;
      end
      363: begin  // instr 259 shl
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 40; c0 = c0 + 1) begin
          t0 = $signed(r336[a1]);
          t1 = t0 << 1;
          r337[a0] = t1[7:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 364;
      end
      364: begin  // instr 260 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 40; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            t0 = $signed(r337[a1]);
            r338[a0] = t0[7:0];
            a0 = a0 + 1;
          end
          a1 = a1 + 1;
        end
        state <= 365;
      end
      365: begin  // instr 261 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 6; c0 = c0 + 1) begin
          t0 = a1;
          r339[a0] = t0[3:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 366;
      end
      366: begin  // instr 262 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 6; c1 = c1 + 1) begin
            t0 = $signed(r339[a1]);
            r340[a0] = t0[3:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 6;
        end
        state <= 367;
      end
      367: begin  // instr 263 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 40; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 6; c1 = c1 + 1) begin
            t0 = $signed(r338[a1]);
            t1 = $signed(r340[a2]);
            t2 = t0 + t1;
            r341[a0] = t2[7:0];
            a0 = a0 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 + 1;
          a2 = a2 - 6;
        end
        state <= 368;
      end
      368: begin  // instr 264 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 40; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r341[a1]);
              r342[a0] = t0[7:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 240;
        end
        state <= 369;
      end
      369: begin  // instr 265 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r331[a1]);
              r343[a0] = t0[1:0];
              a0 = a0 + 1;
            end
          end
        end
        state <= 370;
      end
      370: begin  // instr 266 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 40; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r343[a1]);
              t1 = $signed(r342[a2]);
              t2 = t0 + t1;
              r344[a0] = t2[7:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a2 = a2 - 240;
        end
        state <= 371;
      end
      371: begin  // instr 267 lt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 40; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r344[a1]);
              t1 = $signed(rom9_lit[a2]);
              t2 = (t0 < t1) ? 1 : 0;
              r345[a0] = (t2 != 0);
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 240;
        end
        state <= 372;
      end
      372: begin  // instr 268 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 40; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r344[a1]);
              t1 = $signed(rom18_lit[a2]);
              t2 = t0 + t1;
              r347[a0] = t2[8:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 240;
        end
        state <= 373;
      end
      373: begin  // instr 269 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 40; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = r345[a1];
              t1 = $signed(r344[a2]);
              t2 = $signed(r347[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r348[a0] = t3[7:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
          end
          a1 = a1 - 240;
          a2 = a2 - 240;
          a3 = a3 - 240;
        end
        state <= 374;
      end
      374: begin  // instr 270 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 40; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r348[a1]);
                r349[a0] = t0[7:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 240;
        end
        state <= 375;
      end
      375: begin  // instr 271 gather
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 40; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t9 = 0;
              t0 = $signed(r349[a2]);
              t1 = (t0 < 0) ? 0 : t0;
              t1 = (t1 > 85) ? 85 : t1;
              t2 = t1;
              t9 = t9 + t2;
              t3 = $signed(r335[a1 + t9]);
              r350[a0] = t3[8:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a1 = a1 + 86;
        end
        state <= 376;
      end
      376: begin  // instr 272 mov
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 6; c0 = c0 + 1) begin
          t0 = $signed(rom1_c[a1]);
          t1 = t0;
          r351[a0] = t1[6:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 377;
      end
      377: begin  // instr 273 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r351[a1]);
              r352[a0] = t0[6:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 6;
          end
        end
        state <= 378;
      end
      378: begin  // instr 274 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 40; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r352[a1]);
              t1 = $signed(r350[a2]);
              t2 = t0 + t1;
              r353[a0] = t2[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 6;
          end
          a2 = a2 - 240;
        end
        state <= 379;
      end
      379: begin  // instr 275 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom11_lit[a1]);
        t1 = t0;
        r354[a0] = t1[9:0];
        state <= 380;
      end
      380: begin  // instr 276 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 40; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r354[a1]);
              t1 = $signed(r353[a2]);
              t2 = (t0 < t1) ? t1 : t0;
              r355[a0] = t2[9:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a2 = a2 - 240;
        end
        state <= 381;
      end
      381: begin  // instr 277 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom12_lit[a1]);
        t1 = t0;
        r356[a0] = t1[9:0];
        state <= 382;
      end
      382: begin  // instr 278 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 40; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r356[a1]);
              t1 = $signed(r355[a2]);
              t2 = (t1 < t0) ? t1 : t0;
              r357[a0] = t2[9:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a2 = a2 - 240;
        end
        state <= 383;
      end
      383: begin  // instr 279 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r351[a1]);
              r358[a0] = t0[6:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 6;
          end
        end
        state <= 384;
      end
      384: begin  // instr 280 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 40; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r358[a1]);
              t1 = $signed(r350[a2]);
              t2 = t0 - t1;
              r359[a0] = t2[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 6;
          end
          a2 = a2 - 240;
        end
        state <= 385;
      end
      385: begin  // instr 281 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom11_lit[a1]);
        t1 = t0;
        r360[a0] = t1[9:0];
        state <= 386;
      end
      386: begin  // instr 282 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 40; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r360[a1]);
              t1 = $signed(r359[a2]);
              t2 = (t0 < t1) ? t1 : t0;
              r361[a0] = t2[9:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a2 = a2 - 240;
        end
        state <= 387;
      end
      387: begin  // instr 283 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom12_lit[a1]);
        t1 = t0;
        r362[a0] = t1[9:0];
        state <= 388;
      end
      388: begin  // instr 284 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 40; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r362[a1]);
              t1 = $signed(r361[a2]);
              t2 = (t1 < t0) ? t1 : t0;
              r363[a0] = t2[9:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a2 = a2 - 240;
        end
        state <= 389;
      end
      389: begin  // instr 285 abs
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 40; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r357[a1]);
              t1 = (t0 < 0) ? (0 - t0) : t0;
              r364[a0] = t1[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 240;
        end
        state <= 390;
      end
      390: begin  // instr 286 reduce_max
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 40; c0 = c0 + 1) begin
          r365[a0] = t0[9:0];
          a0 = a0 + 1;
        end
        state <= 391;
      end
      391: begin  // reduce.max.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 40; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r365[a0]);
              t1 = $signed(r364[a1]);
              t2 = (t0 < t1) ? t1 : t0;
              r365[a0] = t2[9:0];
              a1 = a1 + 1;
            end
            a0 = a0 + 1;
          end
        end
        state <= 392;
      end
      392: begin  // instr 287 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 40; c1 = c1 + 1) begin
            t0 = $signed(r365[a1]);
            t1 = $signed(rom13_lit[a2]);
            t2 = t0 - t1;
            r366[a0] = t2[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 40;
        end
        state <= 393;
      end
      393: begin  // instr 288 loop
        k6 = 0;
        state <= 394;
      end
      394: begin  // loop6.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 240; c0 = c0 + 1) begin
          t0 = $signed(r357[a1]);
          r367[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 395;
      end
      395: begin  // loop6.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom13_lit[a1]);
          r368[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 396;
      end
      396: begin  // loop6.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom9_lit[a1]);
          r369[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 397;
      end
      397: begin  // loop6.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 40; c0 = c0 + 1) begin
          t0 = $signed(r366[a1]);
          r370[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 398;
      end
      398: begin  // loop6.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 40; c0 = c0 + 1) begin
          t0 = $signed(r365[a1]);
          r371[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 399;
      end
      399: begin  // loop6.head
        if (k6 == 12) state <= 422;
        else state <= 400;
      end
      400: begin  // instr 289 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r369[a1]);
        t1 = $signed(rom8_lit[a2]);
        t2 = t0 + t1;
        r372[a0] = t2[4:0];
        state <= 401;
      end
      401: begin  // instr 290 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 40; c1 = c1 + 1) begin
            t0 = $signed(r370[a1]);
            t1 = $signed(r371[a2]);
            t2 = t0 + t1;
            r373[a0] = t2[10:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 - 40;
          a2 = a2 - 40;
        end
        state <= 402;
      end
      402: begin  // instr 291 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 40; c1 = c1 + 1) begin
            t0 = $signed(r373[a1]);
            t1 = t0 >>> 1;
            r374[a0] = t1[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 40;
        end
        state <= 403;
      end
      403: begin  // instr 292 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 40; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r374[a1]);
              r375[a0] = t0[9:0];
              a0 = a0 + 1;
            end
            a1 = a1 + 1;
          end
          a1 = a1 - 40;
        end
        state <= 404;
      end
      404: begin  // instr 293 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 40; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r367[a1]);
              t1 = $signed(r375[a2]);
              t2 = t0 - t1;
              r376[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a2 = a2 + 1;
          end
          a1 = a1 - 240;
          a2 = a2 - 40;
        end
        state <= 405;
      end
      405: begin  // instr 294 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 40; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r376[a1]);
              t1 = $signed(rom9_lit[a2]);
              t2 = (t0 < t1) ? t1 : t0;
              r377[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 240;
        end
        state <= 406;
      end
      406: begin  // instr 295 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 40; c0 = c0 + 1) begin
          r378[a0] = t0[13:0];
          a0 = a0 + 1;
        end
        state <= 407;
      end
      407: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 40; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r378[a0]);
              t1 = $signed(r377[a1]);
              t2 = t0 + t1;
              r378[a0] = t2[13:0];
              a1 = a1 + 1;
            end
            a0 = a0 + 1;
          end
        end
        state <= 408;
      end
      408: begin  // instr 296 neg
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 40; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r367[a1]);
              t1 = 0 - t0;
              r379[a0] = t1[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 240;
        end
        state <= 409;
      end
      409: begin  // instr 297 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 40; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r374[a1]);
              r380[a0] = t0[9:0];
              a0 = a0 + 1;
            end
            a1 = a1 + 1;
          end
          a1 = a1 - 40;
        end
        state <= 410;
      end
      410: begin  // instr 298 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 40; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r379[a1]);
              t1 = $signed(r380[a2]);
              t2 = t0 - t1;
              r381[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a2 = a2 + 1;
          end
          a1 = a1 - 240;
          a2 = a2 - 40;
        end
        state <= 411;
      end
      411: begin  // instr 299 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 40; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r381[a1]);
              t1 = $signed(rom9_lit[a2]);
              t2 = (t0 < t1) ? t1 : t0;
              r382[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 240;
        end
        state <= 412;
      end
      412: begin  // instr 300 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 40; c0 = c0 + 1) begin
          r383[a0] = t0[13:0];
          a0 = a0 + 1;
        end
        state <= 413;
      end
      413: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 40; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r383[a0]);
              t1 = $signed(r382[a1]);
              t2 = t0 + t1;
              r383[a0] = t2[13:0];
              a1 = a1 + 1;
            end
            a0 = a0 + 1;
          end
        end
        state <= 414;
      end
      414: begin  // instr 301 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 40; c1 = c1 + 1) begin
            t0 = $signed(r378[a1]);
            t1 = $signed(r383[a2]);
            t2 = t0 + t1;
            r384[a0] = t2[14:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 - 40;
          a2 = a2 - 40;
        end
        state <= 415;
      end
      415: begin  // instr 302 gt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 40; c1 = c1 + 1) begin
            t0 = $signed(r384[a1]);
            t1 = $signed(r368[a2]);
            t2 = (t0 > t1) ? 1 : 0;
            r385[a0] = (t2 != 0);
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 40;
        end
        state <= 416;
      end
      416: begin  // instr 303 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 40; c1 = c1 + 1) begin
            t0 = r385[a1];
            t1 = $signed(r370[a2]);
            t2 = $signed(r374[a3]);
            t3 = (t0 != 0) ? t2 : t1;
            r386[a0] = t3[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
            a3 = a3 + 1;
          end
          a1 = a1 - 40;
          a2 = a2 - 40;
          a3 = a3 - 40;
        end
        state <= 417;
      end
      417: begin  // instr 304 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 40; c1 = c1 + 1) begin
            t0 = r385[a1];
            t1 = $signed(r374[a2]);
            t2 = $signed(r371[a3]);
            t3 = (t0 != 0) ? t2 : t1;
            r387[a0] = t3[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
            a3 = a3 + 1;
          end
          a1 = a1 - 40;
          a2 = a2 - 40;
          a3 = a3 - 40;
        end
        state <= 418;
      end
      418: begin  // loop6.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r372[a1]);
          r369[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 419;
      end
      419: begin  // loop6.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 40; c0 = c0 + 1) begin
          t0 = $signed(r386[a1]);
          r370[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 420;
      end
      420: begin  // loop6.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 40; c0 = c0 + 1) begin
          t0 = $signed(r387[a1]);
          r371[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 421;
      end
      421: begin  // loop6.adv
        k6 = k6 + 1;
        state <= 399;
      end
      422: begin  // loop6.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r369[a1]);
          r388[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 423;
      end
      423: begin  // loop6.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 40; c0 = c0 + 1) begin
          t0 = $signed(r370[a1]);
          r389[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 424;
      end
      424: begin  // loop6.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 40; c0 = c0 + 1) begin
          t0 = $signed(r371[a1]);
          r390[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 425;
      end
      425: begin  // instr 305 abs
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 40; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r363[a1]);
              t1 = (t0 < 0) ? (0 - t0) : t0;
              r391[a0] = t1[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 240;
        end
        state <= 426;
      end
      426: begin  // instr 306 reduce_max
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 40; c0 = c0 + 1) begin
          r392[a0] = t0[9:0];
          a0 = a0 + 1;
        end
        state <= 427;
      end
      427: begin  // reduce.max.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 40; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r392[a0]);
              t1 = $signed(r391[a1]);
              t2 = (t0 < t1) ? t1 : t0;
              r392[a0] = t2[9:0];
              a1 = a1 + 1;
            end
            a0 = a0 + 1;
          end
        end
        state <= 428;
      end
      428: begin  // instr 307 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 40; c1 = c1 + 1) begin
            t0 = $signed(r392[a1]);
            t1 = $signed(rom13_lit[a2]);
            t2 = t0 - t1;
            r393[a0] = t2[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 40;
        end
        state <= 429;
      end
      429: begin  // instr 308 loop
        k7 = 0;
        state <= 430;
      end
      430: begin  // loop7.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 240; c0 = c0 + 1) begin
          t0 = $signed(r363[a1]);
          r394[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 431;
      end
      431: begin  // loop7.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom13_lit[a1]);
          r395[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 432;
      end
      432: begin  // loop7.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom9_lit[a1]);
          r396[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 433;
      end
      433: begin  // loop7.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 40; c0 = c0 + 1) begin
          t0 = $signed(r393[a1]);
          r397[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 434;
      end
      434: begin  // loop7.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 40; c0 = c0 + 1) begin
          t0 = $signed(r392[a1]);
          r398[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 435;
      end
      435: begin  // loop7.head
        if (k7 == 12) state <= 458;
        else state <= 436;
      end
      436: begin  // instr 309 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r396[a1]);
        t1 = $signed(rom8_lit[a2]);
        t2 = t0 + t1;
        r399[a0] = t2[4:0];
        state <= 437;
      end
      437: begin  // instr 310 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 40; c1 = c1 + 1) begin
            t0 = $signed(r397[a1]);
            t1 = $signed(r398[a2]);
            t2 = t0 + t1;
            r400[a0] = t2[10:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 - 40;
          a2 = a2 - 40;
        end
        state <= 438;
      end
      438: begin  // instr 311 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 40; c1 = c1 + 1) begin
            t0 = $signed(r400[a1]);
            t1 = t0 >>> 1;
            r401[a0] = t1[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 40;
        end
        state <= 439;
      end
      439: begin  // instr 312 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 40; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r401[a1]);
              r402[a0] = t0[9:0];
              a0 = a0 + 1;
            end
            a1 = a1 + 1;
          end
          a1 = a1 - 40;
        end
        state <= 440;
      end
      440: begin  // instr 313 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 40; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r394[a1]);
              t1 = $signed(r402[a2]);
              t2 = t0 - t1;
              r403[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a2 = a2 + 1;
          end
          a1 = a1 - 240;
          a2 = a2 - 40;
        end
        state <= 441;
      end
      441: begin  // instr 314 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 40; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r403[a1]);
              t1 = $signed(rom9_lit[a2]);
              t2 = (t0 < t1) ? t1 : t0;
              r404[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 240;
        end
        state <= 442;
      end
      442: begin  // instr 315 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 40; c0 = c0 + 1) begin
          r405[a0] = t0[13:0];
          a0 = a0 + 1;
        end
        state <= 443;
      end
      443: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 40; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r405[a0]);
              t1 = $signed(r404[a1]);
              t2 = t0 + t1;
              r405[a0] = t2[13:0];
              a1 = a1 + 1;
            end
            a0 = a0 + 1;
          end
        end
        state <= 444;
      end
      444: begin  // instr 316 neg
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 40; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r394[a1]);
              t1 = 0 - t0;
              r406[a0] = t1[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 240;
        end
        state <= 445;
      end
      445: begin  // instr 317 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 40; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r401[a1]);
              r407[a0] = t0[9:0];
              a0 = a0 + 1;
            end
            a1 = a1 + 1;
          end
          a1 = a1 - 40;
        end
        state <= 446;
      end
      446: begin  // instr 318 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 40; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r406[a1]);
              t1 = $signed(r407[a2]);
              t2 = t0 - t1;
              r408[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a2 = a2 + 1;
          end
          a1 = a1 - 240;
          a2 = a2 - 40;
        end
        state <= 447;
      end
      447: begin  // instr 319 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 40; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r408[a1]);
              t1 = $signed(rom9_lit[a2]);
              t2 = (t0 < t1) ? t1 : t0;
              r409[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 240;
        end
        state <= 448;
      end
      448: begin  // instr 320 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 40; c0 = c0 + 1) begin
          r410[a0] = t0[13:0];
          a0 = a0 + 1;
        end
        state <= 449;
      end
      449: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 40; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r410[a0]);
              t1 = $signed(r409[a1]);
              t2 = t0 + t1;
              r410[a0] = t2[13:0];
              a1 = a1 + 1;
            end
            a0 = a0 + 1;
          end
        end
        state <= 450;
      end
      450: begin  // instr 321 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 40; c1 = c1 + 1) begin
            t0 = $signed(r405[a1]);
            t1 = $signed(r410[a2]);
            t2 = t0 + t1;
            r411[a0] = t2[14:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 - 40;
          a2 = a2 - 40;
        end
        state <= 451;
      end
      451: begin  // instr 322 gt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 40; c1 = c1 + 1) begin
            t0 = $signed(r411[a1]);
            t1 = $signed(r395[a2]);
            t2 = (t0 > t1) ? 1 : 0;
            r412[a0] = (t2 != 0);
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 40;
        end
        state <= 452;
      end
      452: begin  // instr 323 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 40; c1 = c1 + 1) begin
            t0 = r412[a1];
            t1 = $signed(r397[a2]);
            t2 = $signed(r401[a3]);
            t3 = (t0 != 0) ? t2 : t1;
            r413[a0] = t3[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
            a3 = a3 + 1;
          end
          a1 = a1 - 40;
          a2 = a2 - 40;
          a3 = a3 - 40;
        end
        state <= 453;
      end
      453: begin  // instr 324 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 40; c1 = c1 + 1) begin
            t0 = r412[a1];
            t1 = $signed(r401[a2]);
            t2 = $signed(r398[a3]);
            t3 = (t0 != 0) ? t2 : t1;
            r414[a0] = t3[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
            a3 = a3 + 1;
          end
          a1 = a1 - 40;
          a2 = a2 - 40;
          a3 = a3 - 40;
        end
        state <= 454;
      end
      454: begin  // loop7.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r399[a1]);
          r396[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 455;
      end
      455: begin  // loop7.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 40; c0 = c0 + 1) begin
          t0 = $signed(r413[a1]);
          r397[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 456;
      end
      456: begin  // loop7.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 40; c0 = c0 + 1) begin
          t0 = $signed(r414[a1]);
          r398[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 457;
      end
      457: begin  // loop7.adv
        k7 = k7 + 1;
        state <= 435;
      end
      458: begin  // loop7.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r396[a1]);
          r415[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 459;
      end
      459: begin  // loop7.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 40; c0 = c0 + 1) begin
          t0 = $signed(r397[a1]);
          r416[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 460;
      end
      460: begin  // loop7.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 40; c0 = c0 + 1) begin
          t0 = $signed(r398[a1]);
          r417[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 461;
      end
      461: begin  // instr 325 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 40; c1 = c1 + 1) begin
            t0 = $signed(r390[a1]);
            t1 = $signed(r417[a2]);
            t2 = t0 - t1;
            r418[a0] = t2[10:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 - 40;
          a2 = a2 - 40;
        end
        state <= 462;
      end
      462: begin  // instr 326 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 40; c1 = c1 + 1) begin
            t0 = $signed(r418[a1]);
            t1 = t0 >>> 1;
            r419[a0] = t1[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 40;
        end
        state <= 463;
      end
      463: begin  // instr 327 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom15_lit[a1]);
        t1 = t0;
        r420[a0] = t1[7:0];
        state <= 464;
      end
      464: begin  // instr 328 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 40; c1 = c1 + 1) begin
            t0 = $signed(r420[a1]);
            t1 = $signed(r419[a2]);
            t2 = (t0 < t1) ? t1 : t0;
            r421[a0] = t2[9:0];
            a0 = a0 + 1;
            a2 = a2 + 1;
          end
          a2 = a2 - 40;
        end
        state <= 465;
      end
      465: begin  // instr 329 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom16_lit[a1]);
        t1 = t0;
        r422[a0] = t1[7:0];
        state <= 466;
      end
      466: begin  // instr 330 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 40; c1 = c1 + 1) begin
            t0 = $signed(r422[a1]);
            t1 = $signed(r421[a2]);
            t2 = (t1 < t0) ? t1 : t0;
            r423[a0] = t2[7:0];
            a0 = a0 + 1;
            a2 = a2 + 1;
          end
          a2 = a2 - 40;
        end
        state <= 467;
      end
      467: begin  // instr 331 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r231[a1]);
          t1 = $signed(r331[a2]);
          t2 = t0 - t1;
          r424[a0] = t2[7:0];
          a0 = a0 + 1;
        end
        state <= 468;
      end
      468: begin  // instr 332 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r424[a1]);
          t1 = $signed(rom8_lit[a2]);
          t2 = t0 + t1;
          r425[a0] = t2[7:0];
          a0 = a0 + 1;
        end
        state <= 469;
      end
      469: begin  // instr 333 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r425[a1]);
          t1 = $signed(rom9_lit[a2]);
          t2 = (t0 < t1) ? t1 : t0;
          r426[a0] = t2[7:0];
          a0 = a0 + 1;
        end
        state <= 470;
      end
      470: begin  // instr 334 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r426[a1]);
          t1 = t0 >>> 1;
          r427[a0] = t1[6:0];
          a0 = a0 + 1;
        end
        state <= 471;
      end
      471: begin  // instr 335 concat
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 15; c1 = c1 + 1) begin
            t0 = $signed(r2[a1]);
            r428[a0] = t0[7:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a0 = a0 + 40;
        end
        state <= 472;
      end
      472: begin  // concat
        a0 = 15;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 40; c1 = c1 + 1) begin
            t0 = $signed(r423[a1]);
            r428[a0] = t0[7:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a0 = a0 + 15;
        end
        state <= 473;
      end
      473: begin  // instr 336 shl
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 55; c1 = c1 + 1) begin
            t0 = $signed(r428[a1]);
            t1 = t0 << 1;
            r429[a0] = t1[8:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 55;
        end
        state <= 474;
      end
      474: begin  // instr 337 mov
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(rom0_c[a1]);
            t1 = t0;
            r430[a0] = t1[5:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
        end
        state <= 475;
      end
      475: begin  // instr 338 rev
        a0 = 0;
        a1 = 15;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(r430[a1]);
            r431[a0] = t0[5:0];
            a0 = a0 + 1;
            a1 = a1 - 1;
          end
          a1 = a1 + 32;
        end
        state <= 476;
      end
      476: begin  // instr 339 reshape
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 80; c0 = c0 + 1) begin
          t0 = $signed(r431[a1]);
          r432[a0] = t0[5:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 477;
      end
      477: begin  // instr 340 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 40; c0 = c0 + 1) begin
          t0 = a1;
          r433[a0] = t0[6:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 478;
      end
      478: begin  // instr 341 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 40; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            t0 = $signed(r433[a1]);
            r434[a0] = t0[6:0];
            a0 = a0 + 1;
          end
          a1 = a1 + 1;
        end
        state <= 479;
      end
      479: begin  // instr 342 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 16; c0 = c0 + 1) begin
          t0 = a1;
          r435[a0] = t0[4:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 480;
      end
      480: begin  // instr 343 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(r435[a1]);
            r436[a0] = t0[4:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 16;
        end
        state <= 481;
      end
      481: begin  // instr 344 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 40; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(r434[a1]);
            t1 = $signed(r436[a2]);
            t2 = t0 + t1;
            r437[a0] = t2[6:0];
            a0 = a0 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 + 1;
          a2 = a2 - 16;
        end
        state <= 482;
      end
      482: begin  // instr 345 lt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 40; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(r437[a1]);
            t1 = $signed(rom9_lit[a2]);
            t2 = (t0 < t1) ? 1 : 0;
            r438[a0] = (t2 != 0);
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
        end
        state <= 483;
      end
      483: begin  // instr 346 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 40; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(r437[a1]);
            t1 = $signed(rom19_lit[a2]);
            t2 = t0 + t1;
            r440[a0] = t2[7:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
        end
        state <= 484;
      end
      484: begin  // instr 347 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 40; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = r438[a1];
            t1 = $signed(r437[a2]);
            t2 = $signed(r440[a3]);
            t3 = (t0 != 0) ? t2 : t1;
            r441[a0] = t3[6:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
            a3 = a3 + 1;
          end
        end
        state <= 485;
      end
      485: begin  // instr 348 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 40; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r441[a1]);
              r442[a0] = t0[6:0];
              a0 = a0 + 1;
            end
            a1 = a1 + 1;
          end
        end
        state <= 486;
      end
      486: begin  // instr 349 gather
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 40; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 16; c2 = c2 + 1) begin
              t9 = 0;
              t0 = $signed(r442[a2]);
              t1 = (t0 < 0) ? 0 : t0;
              t1 = (t1 > 54) ? 54 : t1;
              t2 = t1;
              t9 = t9 + t2;
              t3 = $signed(r429[a1 + t9]);
              r443[a0] = t3[8:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a1 = a1 + 55;
          a2 = a2 - 640;
        end
        state <= 487;
      end
      487: begin  // instr 350 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 40; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r443[a1]);
                r444[a0] = t0[8:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 640;
          end
        end
        state <= 488;
      end
      488: begin  // instr 351 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 40; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r432[a1]);
                t1 = $signed(r444[a2]);
                t2 = t0 + t1;
                r445[a0] = t2[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
                a2 = a2 + 1;
              end
              a1 = a1 - 16;
            end
            a2 = a2 - 640;
          end
          a1 = a1 + 16;
        end
        state <= 489;
      end
      489: begin  // instr 352 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom11_lit[a1]);
        t1 = t0;
        r446[a0] = t1[9:0];
        state <= 490;
      end
      490: begin  // instr 353 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 40; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r446[a1]);
                t1 = $signed(r445[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r447[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 640;
          end
          a2 = a2 + 640;
        end
        state <= 491;
      end
      491: begin  // instr 354 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom12_lit[a1]);
        t1 = t0;
        r448[a0] = t1[9:0];
        state <= 492;
      end
      492: begin  // instr 355 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 40; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r448[a1]);
                t1 = $signed(r447[a2]);
                t2 = (t1 < t0) ? t1 : t0;
                r449[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 640;
          end
          a2 = a2 + 640;
        end
        state <= 493;
      end
      493: begin  // instr 356 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 40; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r432[a1]);
                t1 = $signed(r444[a2]);
                t2 = t0 - t1;
                r450[a0] = t2[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
                a2 = a2 + 1;
              end
              a1 = a1 - 16;
            end
            a2 = a2 - 640;
          end
          a1 = a1 + 16;
        end
        state <= 494;
      end
      494: begin  // instr 357 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom11_lit[a1]);
        t1 = t0;
        r451[a0] = t1[9:0];
        state <= 495;
      end
      495: begin  // instr 358 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 40; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r451[a1]);
                t1 = $signed(r450[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r452[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 640;
          end
          a2 = a2 + 640;
        end
        state <= 496;
      end
      496: begin  // instr 359 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom12_lit[a1]);
        t1 = t0;
        r453[a0] = t1[9:0];
        state <= 497;
      end
      497: begin  // instr 360 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 40; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r453[a1]);
                t1 = $signed(r452[a2]);
                t2 = (t1 < t0) ? t1 : t0;
                r454[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 640;
          end
          a2 = a2 + 640;
        end
        state <= 498;
      end
      498: begin  // instr 361 abs
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 40; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r449[a1]);
                t1 = (t0 < 0) ? (0 - t0) : t0;
                r455[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 640;
          end
          a1 = a1 + 640;
        end
        state <= 499;
      end
      499: begin  // instr 362 reduce_max
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 200; c0 = c0 + 1) begin
          r456[a0] = t0[9:0];
          a0 = a0 + 1;
        end
        state <= 500;
      end
      500: begin  // reduce.max.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 40; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r456[a0]);
                t1 = $signed(r455[a1]);
                t2 = (t0 < t1) ? t1 : t0;
                r456[a0] = t2[9:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 501;
      end
      501: begin  // instr 363 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 40; c2 = c2 + 1) begin
              t0 = $signed(r456[a1]);
              t1 = $signed(rom13_lit[a2]);
              t2 = t0 - t1;
              r457[a0] = t2[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 40;
          end
          a1 = a1 + 40;
        end
        state <= 502;
      end
      502: begin  // instr 364 loop
        k8 = 0;
        state <= 503;
      end
      503: begin  // loop8.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 3200; c0 = c0 + 1) begin
          t0 = $signed(r449[a1]);
          r458[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 504;
      end
      504: begin  // loop8.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom13_lit[a1]);
          r459[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 505;
      end
      505: begin  // loop8.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom9_lit[a1]);
          r460[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 506;
      end
      506: begin  // loop8.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 200; c0 = c0 + 1) begin
          t0 = $signed(r457[a1]);
          r461[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 507;
      end
      507: begin  // loop8.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 200; c0 = c0 + 1) begin
          t0 = $signed(r456[a1]);
          r462[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 508;
      end
      508: begin  // loop8.head
        if (k8 == 12) state <= 531;
        else state <= 509;
      end
      509: begin  // instr 365 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r460[a1]);
        t1 = $signed(rom8_lit[a2]);
        t2 = t0 + t1;
        r463[a0] = t2[4:0];
        state <= 510;
      end
      510: begin  // instr 366 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 40; c2 = c2 + 1) begin
              t0 = $signed(r461[a1]);
              t1 = $signed(r462[a2]);
              t2 = t0 + t1;
              r464[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 40;
            a2 = a2 - 40;
          end
          a1 = a1 + 40;
          a2 = a2 + 40;
        end
        state <= 511;
      end
      511: begin  // instr 367 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 40; c2 = c2 + 1) begin
              t0 = $signed(r464[a1]);
              t1 = t0 >>> 1;
              r465[a0] = t1[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 40;
          end
          a1 = a1 + 40;
        end
        state <= 512;
      end
      512: begin  // instr 368 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 40; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r465[a1]);
                r466[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 40;
          end
          a1 = a1 + 40;
        end
        state <= 513;
      end
      513: begin  // instr 369 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 40; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r458[a1]);
                t1 = $signed(r466[a2]);
                t2 = t0 - t1;
                r467[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 640;
            a2 = a2 - 40;
          end
          a1 = a1 + 640;
          a2 = a2 + 40;
        end
        state <= 514;
      end
      514: begin  // instr 370 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 40; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r467[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r468[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 640;
          end
          a1 = a1 + 640;
        end
        state <= 515;
      end
      515: begin  // instr 371 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 200; c0 = c0 + 1) begin
          r469[a0] = t0[14:0];
          a0 = a0 + 1;
        end
        state <= 516;
      end
      516: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 40; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r469[a0]);
                t1 = $signed(r468[a1]);
                t2 = t0 + t1;
                r469[a0] = t2[14:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 517;
      end
      517: begin  // instr 372 neg
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 40; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r458[a1]);
                t1 = 0 - t0;
                r470[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 640;
          end
          a1 = a1 + 640;
        end
        state <= 518;
      end
      518: begin  // instr 373 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 40; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r465[a1]);
                r471[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 40;
          end
          a1 = a1 + 40;
        end
        state <= 519;
      end
      519: begin  // instr 374 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 40; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r470[a1]);
                t1 = $signed(r471[a2]);
                t2 = t0 - t1;
                r472[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 640;
            a2 = a2 - 40;
          end
          a1 = a1 + 640;
          a2 = a2 + 40;
        end
        state <= 520;
      end
      520: begin  // instr 375 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 40; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r472[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r473[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 640;
          end
          a1 = a1 + 640;
        end
        state <= 521;
      end
      521: begin  // instr 376 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 200; c0 = c0 + 1) begin
          r474[a0] = t0[14:0];
          a0 = a0 + 1;
        end
        state <= 522;
      end
      522: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 40; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r474[a0]);
                t1 = $signed(r473[a1]);
                t2 = t0 + t1;
                r474[a0] = t2[14:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 523;
      end
      523: begin  // instr 377 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 40; c2 = c2 + 1) begin
              t0 = $signed(r469[a1]);
              t1 = $signed(r474[a2]);
              t2 = t0 + t1;
              r475[a0] = t2[15:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 40;
            a2 = a2 - 40;
          end
          a1 = a1 + 40;
          a2 = a2 + 40;
        end
        state <= 524;
      end
      524: begin  // instr 378 gt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 40; c2 = c2 + 1) begin
              t0 = $signed(r475[a1]);
              t1 = $signed(r459[a2]);
              t2 = (t0 > t1) ? 1 : 0;
              r476[a0] = (t2 != 0);
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 40;
          end
          a1 = a1 + 40;
        end
        state <= 525;
      end
      525: begin  // instr 379 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 40; c2 = c2 + 1) begin
              t0 = r476[a1];
              t1 = $signed(r461[a2]);
              t2 = $signed(r465[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r477[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 40;
            a2 = a2 - 40;
            a3 = a3 - 40;
          end
          a1 = a1 + 40;
          a2 = a2 + 40;
          a3 = a3 + 40;
        end
        state <= 526;
      end
      526: begin  // instr 380 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 40; c2 = c2 + 1) begin
              t0 = r476[a1];
              t1 = $signed(r465[a2]);
              t2 = $signed(r462[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r478[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 40;
            a2 = a2 - 40;
            a3 = a3 - 40;
          end
          a1 = a1 + 40;
          a2 = a2 + 40;
          a3 = a3 + 40;
        end
        state <= 527;
      end
      527: begin  // loop8.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r463[a1]);
          r460[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 528;
      end
      528: begin  // loop8.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 200; c0 = c0 + 1) begin
          t0 = $signed(r477[a1]);
          r461[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 529;
      end
      529: begin  // loop8.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 200; c0 = c0 + 1) begin
          t0 = $signed(r478[a1]);
          r462[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 530;
      end
      530: begin  // loop8.adv
        k8 = k8 + 1;
        state <= 508;
      end
      531: begin  // loop8.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r460[a1]);
          r479[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 532;
      end
      532: begin  // loop8.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 200; c0 = c0 + 1) begin
          t0 = $signed(r461[a1]);
          r480[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 533;
      end
      533: begin  // loop8.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 200; c0 = c0 + 1) begin
          t0 = $signed(r462[a1]);
          r481[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 534;
      end
      534: begin  // instr 381 abs
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 40; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r454[a1]);
                t1 = (t0 < 0) ? (0 - t0) : t0;
                r482[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 640;
          end
          a1 = a1 + 640;
        end
        state <= 535;
      end
      535: begin  // instr 382 reduce_max
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 200; c0 = c0 + 1) begin
          r483[a0] = t0[9:0];
          a0 = a0 + 1;
        end
        state <= 536;
      end
      536: begin  // reduce.max.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 40; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r483[a0]);
                t1 = $signed(r482[a1]);
                t2 = (t0 < t1) ? t1 : t0;
                r483[a0] = t2[9:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 537;
      end
      537: begin  // instr 383 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 40; c2 = c2 + 1) begin
              t0 = $signed(r483[a1]);
              t1 = $signed(rom13_lit[a2]);
              t2 = t0 - t1;
              r484[a0] = t2[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 40;
          end
          a1 = a1 + 40;
        end
        state <= 538;
      end
      538: begin  // instr 384 loop
        k9 = 0;
        state <= 539;
      end
      539: begin  // loop9.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 3200; c0 = c0 + 1) begin
          t0 = $signed(r454[a1]);
          r485[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 540;
      end
      540: begin  // loop9.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom13_lit[a1]);
          r486[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 541;
      end
      541: begin  // loop9.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom9_lit[a1]);
          r487[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 542;
      end
      542: begin  // loop9.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 200; c0 = c0 + 1) begin
          t0 = $signed(r484[a1]);
          r488[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 543;
      end
      543: begin  // loop9.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 200; c0 = c0 + 1) begin
          t0 = $signed(r483[a1]);
          r489[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 544;
      end
      544: begin  // loop9.head
        if (k9 == 12) state <= 567;
        else state <= 545;
      end
      545: begin  // instr 385 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r487[a1]);
        t1 = $signed(rom8_lit[a2]);
        t2 = t0 + t1;
        r490[a0] = t2[4:0];
        state <= 546;
      end
      546: begin  // instr 386 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 40; c2 = c2 + 1) begin
              t0 = $signed(r488[a1]);
              t1 = $signed(r489[a2]);
              t2 = t0 + t1;
              r491[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 40;
            a2 = a2 - 40;
          end
          a1 = a1 + 40;
          a2 = a2 + 40;
        end
        state <= 547;
      end
      547: begin  // instr 387 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 40; c2 = c2 + 1) begin
              t0 = $signed(r491[a1]);
              t1 = t0 >>> 1;
              r492[a0] = t1[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 40;
          end
          a1 = a1 + 40;
        end
        state <= 548;
      end
      548: begin  // instr 388 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 40; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r492[a1]);
                r493[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 40;
          end
          a1 = a1 + 40;
        end
        state <= 549;
      end
      549: begin  // instr 389 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 40; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r485[a1]);
                t1 = $signed(r493[a2]);
                t2 = t0 - t1;
                r494[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 640;
            a2 = a2 - 40;
          end
          a1 = a1 + 640;
          a2 = a2 + 40;
        end
        state <= 550;
      end
      550: begin  // instr 390 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 40; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r494[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r495[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 640;
          end
          a1 = a1 + 640;
        end
        state <= 551;
      end
      551: begin  // instr 391 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 200; c0 = c0 + 1) begin
          r496[a0] = t0[14:0];
          a0 = a0 + 1;
        end
        state <= 552;
      end
      552: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 40; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r496[a0]);
                t1 = $signed(r495[a1]);
                t2 = t0 + t1;
                r496[a0] = t2[14:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 553;
      end
      553: begin  // instr 392 neg
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 40; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r485[a1]);
                t1 = 0 - t0;
                r497[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 640;
          end
          a1 = a1 + 640;
        end
        state <= 554;
      end
      554: begin  // instr 393 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 40; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r492[a1]);
                r498[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 40;
          end
          a1 = a1 + 40;
        end
        state <= 555;
      end
      555: begin  // instr 394 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 40; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r497[a1]);
                t1 = $signed(r498[a2]);
                t2 = t0 - t1;
                r499[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 640;
            a2 = a2 - 40;
          end
          a1 = a1 + 640;
          a2 = a2 + 40;
        end
        state <= 556;
      end
      556: begin  // instr 395 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 40; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r499[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r500[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 640;
          end
          a1 = a1 + 640;
        end
        state <= 557;
      end
      557: begin  // instr 396 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 200; c0 = c0 + 1) begin
          r501[a0] = t0[14:0];
          a0 = a0 + 1;
        end
        state <= 558;
      end
      558: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 40; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r501[a0]);
                t1 = $signed(r500[a1]);
                t2 = t0 + t1;
                r501[a0] = t2[14:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 559;
      end
      559: begin  // instr 397 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 40; c2 = c2 + 1) begin
              t0 = $signed(r496[a1]);
              t1 = $signed(r501[a2]);
              t2 = t0 + t1;
              r502[a0] = t2[15:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 40;
            a2 = a2 - 40;
          end
          a1 = a1 + 40;
          a2 = a2 + 40;
        end
        state <= 560;
      end
      560: begin  // instr 398 gt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 40; c2 = c2 + 1) begin
              t0 = $signed(r502[a1]);
              t1 = $signed(r486[a2]);
              t2 = (t0 > t1) ? 1 : 0;
              r503[a0] = (t2 != 0);
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 40;
          end
          a1 = a1 + 40;
        end
        state <= 561;
      end
      561: begin  // instr 399 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 40; c2 = c2 + 1) begin
              t0 = r503[a1];
              t1 = $signed(r488[a2]);
              t2 = $signed(r492[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r504[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 40;
            a2 = a2 - 40;
            a3 = a3 - 40;
          end
          a1 = a1 + 40;
          a2 = a2 + 40;
          a3 = a3 + 40;
        end
        state <= 562;
      end
      562: begin  // instr 400 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 40; c2 = c2 + 1) begin
              t0 = r503[a1];
              t1 = $signed(r492[a2]);
              t2 = $signed(r489[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r505[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 40;
            a2 = a2 - 40;
            a3 = a3 - 40;
          end
          a1 = a1 + 40;
          a2 = a2 + 40;
          a3 = a3 + 40;
        end
        state <= 563;
      end
      563: begin  // loop9.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r490[a1]);
          r487[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 564;
      end
      564: begin  // loop9.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 200; c0 = c0 + 1) begin
          t0 = $signed(r504[a1]);
          r488[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 565;
      end
      565: begin  // loop9.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 200; c0 = c0 + 1) begin
          t0 = $signed(r505[a1]);
          r489[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 566;
      end
      566: begin  // loop9.adv
        k9 = k9 + 1;
        state <= 544;
      end
      567: begin  // loop9.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r487[a1]);
          r506[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 568;
      end
      568: begin  // loop9.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 200; c0 = c0 + 1) begin
          t0 = $signed(r488[a1]);
          r507[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 569;
      end
      569: begin  // loop9.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 200; c0 = c0 + 1) begin
          t0 = $signed(r489[a1]);
          r508[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 570;
      end
      570: begin  // instr 401 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 40; c2 = c2 + 1) begin
              t0 = $signed(r481[a1]);
              t1 = $signed(r508[a2]);
              t2 = t0 - t1;
              r509[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 40;
            a2 = a2 - 40;
          end
          a1 = a1 + 40;
          a2 = a2 + 40;
        end
        state <= 571;
      end
      571: begin  // instr 402 transpose
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 40; c2 = c2 + 1) begin
              t0 = $signed(r509[a1]);
              r510[a0] = t0[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 160;
        end
        state <= 572;
      end
      572: begin  // instr 403 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            t0 = $signed(r427[a1]);
            r511[a0] = t0[6:0];
            a0 = a0 + 1;
          end
        end
        state <= 573;
      end
      573: begin  // instr 404 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 40; c2 = c2 + 1) begin
              t0 = $signed(r510[a1]);
              t1 = $signed(rom9_lit[a2]);
              t2 = (t0 < t1) ? t1 : t0;
              r512[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 200;
        end
        state <= 574;
      end
      574: begin  // instr 405 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 40; c2 = c2 + 1) begin
              t0 = a1;
              r513[a0] = t0[6:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 40;
          end
        end
        state <= 575;
      end
      575: begin  // instr 406 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r511[a1]);
              r514[a0] = t0[6:0];
              a0 = a0 + 1;
            end
          end
        end
        state <= 576;
      end
      576: begin  // instr 407 lt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 40; c2 = c2 + 1) begin
              t0 = $signed(r513[a1]);
              t1 = $signed(r514[a2]);
              t2 = (t0 < t1) ? 1 : 0;
              r515[a0] = (t2 != 0);
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 200;
        end
        state <= 577;
      end
      577: begin  // instr 408 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom9_lit[a1]);
        t1 = t0;
        r516[a0] = t1[0:0];
        state <= 578;
      end
      578: begin  // instr 409 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 40; c2 = c2 + 1) begin
              t0 = $signed(r516[a1]);
              r517[a0] = t0[0:0];
              a0 = a0 + 1;
            end
          end
        end
        state <= 579;
      end
      579: begin  // instr 410 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 40; c2 = c2 + 1) begin
              t0 = r515[a1];
              t1 = $signed(r517[a2]);
              t2 = $signed(r512[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r518[a0] = t3[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
          end
          a1 = a1 - 200;
          a2 = a2 - 200;
          a3 = a3 - 200;
        end
        state <= 580;
      end
      580: begin  // instr 411 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          r519[a0] = t0[15:0];
          a0 = a0 + 1;
        end
        state <= 581;
      end
      581: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 40; c2 = c2 + 1) begin
              t0 = $signed(r519[a0]);
              t1 = $signed(r518[a1]);
              t2 = t0 + t1;
              r519[a0] = t2[15:0];
              a1 = a1 + 1;
            end
            a0 = a0 + 1;
          end
        end
        state <= 582;
      end
      582: begin  // instr 412 shl
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            t0 = $signed(r519[a1]);
            t1 = t0 << 2;
            r521[a0] = t1[17:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 5;
        end
        state <= 583;
      end
      583: begin  // instr 413 lt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r427[a1]);
          t1 = $signed(rom9_lit[a2]);
          t2 = (t0 < t1) ? 1 : 0;
          r522[a0] = (t2 != 0);
          a0 = a0 + 1;
        end
        state <= 584;
      end
      584: begin  // instr 414 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r427[a1]);
          t1 = $signed(rom19_lit[a2]);
          t2 = t0 + t1;
          r523[a0] = t2[7:0];
          a0 = a0 + 1;
        end
        state <= 585;
      end
      585: begin  // instr 415 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = r522[a1];
          t1 = $signed(r427[a2]);
          t2 = $signed(r523[a3]);
          t3 = (t0 != 0) ? t2 : t1;
          r524[a0] = t3[6:0];
          a0 = a0 + 1;
        end
        state <= 586;
      end
      586: begin  // instr 416 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            t0 = $signed(r524[a1]);
            r525[a0] = t0[6:0];
            a0 = a0 + 1;
          end
        end
        state <= 587;
      end
      587: begin  // instr 417 gather
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 15; c1 = c1 + 1) begin
            t9 = 0;
            t0 = $signed(r525[a2]);
            t1 = (t0 < 0) ? 0 : t0;
            t1 = (t1 > 40) ? 40 : t1;
            t2 = t1;
            t9 = t9 + t2;
            t3 = $signed(r428[a1 + t9]);
            r526[a0] = t3[7:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 + 40;
          a2 = a2 + 1;
        end
        state <= 588;
      end
      588: begin  // instr 418 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r8[a1]);
          t1 = $signed(r427[a2]);
          t2 = t0 + t1;
          r527[a0] = t2;
          a0 = a0 + 1;
        end
        state <= 589;
      end
      589: begin  // instr 419 and
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r8[a1]);
          t1 = $signed(rom8_lit[a2]);
          t2 = t0 & t1;
          r528[a0] = t2[1:0];
          a0 = a0 + 1;
        end
        state <= 590;
      end
      590: begin  // instr 420 slice
        a0 = 0;
        a1 = 10;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 45; c1 = c1 + 1) begin
            t0 = $signed(r428[a1]);
            r529[a0] = t0[7:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 + 10;
        end
        state <= 591;
      end
      591: begin  // instr 421 shl
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 45; c1 = c1 + 1) begin
            t0 = $signed(r529[a1]);
            t1 = t0 << 1;
            r530[a0] = t1[8:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 45;
        end
        state <= 592;
      end
      592: begin  // instr 422 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom9_lit[a1]);
        t1 = t0;
        r531[a0] = t1[0:0];
        state <= 593;
      end
      593: begin  // instr 423 pad
        t0 = $signed(r531[0]);
        a0 = 0;
        for (c0 = 0; c0 < 46; c0 = c0 + 1) begin
          r532[a0] = t0[8:0];
          a0 = a0 + 1;
        end
        state <= 594;
      end
      594: begin  // pad.scatter
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 45; c1 = c1 + 1) begin
            t1 = $signed(r530[a1]);
            r532[a0] = t1[8:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a0 = a0 + 1;
        end
        state <= 595;
      end
      595: begin  // instr 424 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 20; c0 = c0 + 1) begin
          t0 = a1;
          r533[a0] = t0[5:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 596;
      end
      596: begin  // instr 425 shl
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 20; c0 = c0 + 1) begin
          t0 = $signed(r533[a1]);
          t1 = t0 << 1;
          r534[a0] = t1[6:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 597;
      end
      597: begin  // instr 426 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 20; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            t0 = $signed(r534[a1]);
            r535[a0] = t0[6:0];
            a0 = a0 + 1;
          end
          a1 = a1 + 1;
        end
        state <= 598;
      end
      598: begin  // instr 427 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 6; c0 = c0 + 1) begin
          t0 = a1;
          r536[a0] = t0[3:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 599;
      end
      599: begin  // instr 428 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 6; c1 = c1 + 1) begin
            t0 = $signed(r536[a1]);
            r537[a0] = t0[3:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 6;
        end
        state <= 600;
      end
      600: begin  // instr 429 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 20; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 6; c1 = c1 + 1) begin
            t0 = $signed(r535[a1]);
            t1 = $signed(r537[a2]);
            t2 = t0 + t1;
            r538[a0] = t2[6:0];
            a0 = a0 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 + 1;
          a2 = a2 - 6;
        end
        state <= 601;
      end
      601: begin  // instr 430 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 20; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r538[a1]);
              r539[a0] = t0[6:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 120;
        end
        state <= 602;
      end
      602: begin  // instr 431 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r528[a1]);
              r540[a0] = t0[1:0];
              a0 = a0 + 1;
            end
          end
        end
        state <= 603;
      end
      603: begin  // instr 432 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 20; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r540[a1]);
              t1 = $signed(r539[a2]);
              t2 = t0 + t1;
              r541[a0] = t2[6:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a2 = a2 - 120;
        end
        state <= 604;
      end
      604: begin  // instr 433 lt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 20; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r541[a1]);
              t1 = $signed(rom9_lit[a2]);
              t2 = (t0 < t1) ? 1 : 0;
              r542[a0] = (t2 != 0);
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 120;
        end
        state <= 605;
      end
      605: begin  // instr 434 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 20; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r541[a1]);
              t1 = $signed(rom21_lit[a2]);
              t2 = t0 + t1;
              r544[a0] = t2[7:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 120;
        end
        state <= 606;
      end
      606: begin  // instr 435 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 20; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = r542[a1];
              t1 = $signed(r541[a2]);
              t2 = $signed(r544[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r545[a0] = t3[6:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
          end
          a1 = a1 - 120;
          a2 = a2 - 120;
          a3 = a3 - 120;
        end
        state <= 607;
      end
      607: begin  // instr 436 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 20; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r545[a1]);
                r546[a0] = t0[6:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 120;
        end
        state <= 608;
      end
      608: begin  // instr 437 gather
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 20; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t9 = 0;
              t0 = $signed(r546[a2]);
              t1 = (t0 < 0) ? 0 : t0;
              t1 = (t1 > 45) ? 45 : t1;
              t2 = t1;
              t9 = t9 + t2;
              t3 = $signed(r532[a1 + t9]);
              r547[a0] = t3[8:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a1 = a1 + 46;
        end
        state <= 609;
      end
      609: begin  // instr 438 mov
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 6; c0 = c0 + 1) begin
          t0 = $signed(rom1_c[a1]);
          t1 = t0;
          r548[a0] = t1[6:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 610;
      end
      610: begin  // instr 439 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r548[a1]);
              r549[a0] = t0[6:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 6;
          end
        end
        state <= 611;
      end
      611: begin  // instr 440 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 20; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r549[a1]);
              t1 = $signed(r547[a2]);
              t2 = t0 + t1;
              r550[a0] = t2[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 6;
          end
          a2 = a2 - 120;
        end
        state <= 612;
      end
      612: begin  // instr 441 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom11_lit[a1]);
        t1 = t0;
        r551[a0] = t1[9:0];
        state <= 613;
      end
      613: begin  // instr 442 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 20; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r551[a1]);
              t1 = $signed(r550[a2]);
              t2 = (t0 < t1) ? t1 : t0;
              r552[a0] = t2[9:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a2 = a2 - 120;
        end
        state <= 614;
      end
      614: begin  // instr 443 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom12_lit[a1]);
        t1 = t0;
        r553[a0] = t1[9:0];
        state <= 615;
      end
      615: begin  // instr 444 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 20; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r553[a1]);
              t1 = $signed(r552[a2]);
              t2 = (t1 < t0) ? t1 : t0;
              r554[a0] = t2[9:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a2 = a2 - 120;
        end
        state <= 616;
      end
      616: begin  // instr 445 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r548[a1]);
              r555[a0] = t0[6:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 6;
          end
        end
        state <= 617;
      end
      617: begin  // instr 446 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 20; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r555[a1]);
              t1 = $signed(r547[a2]);
              t2 = t0 - t1;
              r556[a0] = t2[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 6;
          end
          a2 = a2 - 120;
        end
        state <= 618;
      end
      618: begin  // instr 447 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom11_lit[a1]);
        t1 = t0;
        r557[a0] = t1[9:0];
        state <= 619;
      end
      619: begin  // instr 448 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 20; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r557[a1]);
              t1 = $signed(r556[a2]);
              t2 = (t0 < t1) ? t1 : t0;
              r558[a0] = t2[9:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a2 = a2 - 120;
        end
        state <= 620;
      end
      620: begin  // instr 449 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom12_lit[a1]);
        t1 = t0;
        r559[a0] = t1[9:0];
        state <= 621;
      end
      621: begin  // instr 450 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 20; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r559[a1]);
              t1 = $signed(r558[a2]);
              t2 = (t1 < t0) ? t1 : t0;
              r560[a0] = t2[9:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a2 = a2 - 120;
        end
        state <= 622;
      end
      622: begin  // instr 451 abs
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 20; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r554[a1]);
              t1 = (t0 < 0) ? (0 - t0) : t0;
              r561[a0] = t1[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 120;
        end
        state <= 623;
      end
      623: begin  // instr 452 reduce_max
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 20; c0 = c0 + 1) begin
          r562[a0] = t0[9:0];
          a0 = a0 + 1;
        end
        state <= 624;
      end
      624: begin  // reduce.max.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 20; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r562[a0]);
              t1 = $signed(r561[a1]);
              t2 = (t0 < t1) ? t1 : t0;
              r562[a0] = t2[9:0];
              a1 = a1 + 1;
            end
            a0 = a0 + 1;
          end
        end
        state <= 625;
      end
      625: begin  // instr 453 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 20; c1 = c1 + 1) begin
            t0 = $signed(r562[a1]);
            t1 = $signed(rom13_lit[a2]);
            t2 = t0 - t1;
            r563[a0] = t2[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 20;
        end
        state <= 626;
      end
      626: begin  // instr 454 loop
        k10 = 0;
        state <= 627;
      end
      627: begin  // loop10.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 120; c0 = c0 + 1) begin
          t0 = $signed(r554[a1]);
          r564[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 628;
      end
      628: begin  // loop10.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom13_lit[a1]);
          r565[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 629;
      end
      629: begin  // loop10.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom9_lit[a1]);
          r566[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 630;
      end
      630: begin  // loop10.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 20; c0 = c0 + 1) begin
          t0 = $signed(r563[a1]);
          r567[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 631;
      end
      631: begin  // loop10.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 20; c0 = c0 + 1) begin
          t0 = $signed(r562[a1]);
          r568[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 632;
      end
      632: begin  // loop10.head
        if (k10 == 12) state <= 655;
        else state <= 633;
      end
      633: begin  // instr 455 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r566[a1]);
        t1 = $signed(rom8_lit[a2]);
        t2 = t0 + t1;
        r569[a0] = t2[4:0];
        state <= 634;
      end
      634: begin  // instr 456 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 20; c1 = c1 + 1) begin
            t0 = $signed(r567[a1]);
            t1 = $signed(r568[a2]);
            t2 = t0 + t1;
            r570[a0] = t2[10:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 - 20;
          a2 = a2 - 20;
        end
        state <= 635;
      end
      635: begin  // instr 457 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 20; c1 = c1 + 1) begin
            t0 = $signed(r570[a1]);
            t1 = t0 >>> 1;
            r571[a0] = t1[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 20;
        end
        state <= 636;
      end
      636: begin  // instr 458 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 20; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r571[a1]);
              r572[a0] = t0[9:0];
              a0 = a0 + 1;
            end
            a1 = a1 + 1;
          end
          a1 = a1 - 20;
        end
        state <= 637;
      end
      637: begin  // instr 459 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 20; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r564[a1]);
              t1 = $signed(r572[a2]);
              t2 = t0 - t1;
              r573[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a2 = a2 + 1;
          end
          a1 = a1 - 120;
          a2 = a2 - 20;
        end
        state <= 638;
      end
      638: begin  // instr 460 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 20; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r573[a1]);
              t1 = $signed(rom9_lit[a2]);
              t2 = (t0 < t1) ? t1 : t0;
              r574[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 120;
        end
        state <= 639;
      end
      639: begin  // instr 461 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 20; c0 = c0 + 1) begin
          r575[a0] = t0[13:0];
          a0 = a0 + 1;
        end
        state <= 640;
      end
      640: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 20; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r575[a0]);
              t1 = $signed(r574[a1]);
              t2 = t0 + t1;
              r575[a0] = t2[13:0];
              a1 = a1 + 1;
            end
            a0 = a0 + 1;
          end
        end
        state <= 641;
      end
      641: begin  // instr 462 neg
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 20; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r564[a1]);
              t1 = 0 - t0;
              r576[a0] = t1[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 120;
        end
        state <= 642;
      end
      642: begin  // instr 463 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 20; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r571[a1]);
              r577[a0] = t0[9:0];
              a0 = a0 + 1;
            end
            a1 = a1 + 1;
          end
          a1 = a1 - 20;
        end
        state <= 643;
      end
      643: begin  // instr 464 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 20; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r576[a1]);
              t1 = $signed(r577[a2]);
              t2 = t0 - t1;
              r578[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a2 = a2 + 1;
          end
          a1 = a1 - 120;
          a2 = a2 - 20;
        end
        state <= 644;
      end
      644: begin  // instr 465 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 20; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r578[a1]);
              t1 = $signed(rom9_lit[a2]);
              t2 = (t0 < t1) ? t1 : t0;
              r579[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 120;
        end
        state <= 645;
      end
      645: begin  // instr 466 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 20; c0 = c0 + 1) begin
          r580[a0] = t0[13:0];
          a0 = a0 + 1;
        end
        state <= 646;
      end
      646: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 20; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r580[a0]);
              t1 = $signed(r579[a1]);
              t2 = t0 + t1;
              r580[a0] = t2[13:0];
              a1 = a1 + 1;
            end
            a0 = a0 + 1;
          end
        end
        state <= 647;
      end
      647: begin  // instr 467 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 20; c1 = c1 + 1) begin
            t0 = $signed(r575[a1]);
            t1 = $signed(r580[a2]);
            t2 = t0 + t1;
            r581[a0] = t2[14:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 - 20;
          a2 = a2 - 20;
        end
        state <= 648;
      end
      648: begin  // instr 468 gt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 20; c1 = c1 + 1) begin
            t0 = $signed(r581[a1]);
            t1 = $signed(r565[a2]);
            t2 = (t0 > t1) ? 1 : 0;
            r582[a0] = (t2 != 0);
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 20;
        end
        state <= 649;
      end
      649: begin  // instr 469 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 20; c1 = c1 + 1) begin
            t0 = r582[a1];
            t1 = $signed(r567[a2]);
            t2 = $signed(r571[a3]);
            t3 = (t0 != 0) ? t2 : t1;
            r583[a0] = t3[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
            a3 = a3 + 1;
          end
          a1 = a1 - 20;
          a2 = a2 - 20;
          a3 = a3 - 20;
        end
        state <= 650;
      end
      650: begin  // instr 470 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 20; c1 = c1 + 1) begin
            t0 = r582[a1];
            t1 = $signed(r571[a2]);
            t2 = $signed(r568[a3]);
            t3 = (t0 != 0) ? t2 : t1;
            r584[a0] = t3[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
            a3 = a3 + 1;
          end
          a1 = a1 - 20;
          a2 = a2 - 20;
          a3 = a3 - 20;
        end
        state <= 651;
      end
      651: begin  // loop10.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r569[a1]);
          r566[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 652;
      end
      652: begin  // loop10.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 20; c0 = c0 + 1) begin
          t0 = $signed(r583[a1]);
          r567[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 653;
      end
      653: begin  // loop10.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 20; c0 = c0 + 1) begin
          t0 = $signed(r584[a1]);
          r568[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 654;
      end
      654: begin  // loop10.adv
        k10 = k10 + 1;
        state <= 632;
      end
      655: begin  // loop10.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r566[a1]);
          r585[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 656;
      end
      656: begin  // loop10.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 20; c0 = c0 + 1) begin
          t0 = $signed(r567[a1]);
          r586[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 657;
      end
      657: begin  // loop10.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 20; c0 = c0 + 1) begin
          t0 = $signed(r568[a1]);
          r587[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 658;
      end
      658: begin  // instr 471 abs
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 20; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r560[a1]);
              t1 = (t0 < 0) ? (0 - t0) : t0;
              r588[a0] = t1[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 120;
        end
        state <= 659;
      end
      659: begin  // instr 472 reduce_max
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 20; c0 = c0 + 1) begin
          r589[a0] = t0[9:0];
          a0 = a0 + 1;
        end
        state <= 660;
      end
      660: begin  // reduce.max.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 20; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r589[a0]);
              t1 = $signed(r588[a1]);
              t2 = (t0 < t1) ? t1 : t0;
              r589[a0] = t2[9:0];
              a1 = a1 + 1;
            end
            a0 = a0 + 1;
          end
        end
        state <= 661;
      end
      661: begin  // instr 473 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 20; c1 = c1 + 1) begin
            t0 = $signed(r589[a1]);
            t1 = $signed(rom13_lit[a2]);
            t2 = t0 - t1;
            r590[a0] = t2[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 20;
        end
        state <= 662;
      end
      662: begin  // instr 474 loop
        k11 = 0;
        state <= 663;
      end
      663: begin  // loop11.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 120; c0 = c0 + 1) begin
          t0 = $signed(r560[a1]);
          r591[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 664;
      end
      664: begin  // loop11.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom13_lit[a1]);
          r592[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 665;
      end
      665: begin  // loop11.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom9_lit[a1]);
          r593[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 666;
      end
      666: begin  // loop11.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 20; c0 = c0 + 1) begin
          t0 = $signed(r590[a1]);
          r594[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 667;
      end
      667: begin  // loop11.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 20; c0 = c0 + 1) begin
          t0 = $signed(r589[a1]);
          r595[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 668;
      end
      668: begin  // loop11.head
        if (k11 == 12) state <= 691;
        else state <= 669;
      end
      669: begin  // instr 475 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r593[a1]);
        t1 = $signed(rom8_lit[a2]);
        t2 = t0 + t1;
        r596[a0] = t2[4:0];
        state <= 670;
      end
      670: begin  // instr 476 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 20; c1 = c1 + 1) begin
            t0 = $signed(r594[a1]);
            t1 = $signed(r595[a2]);
            t2 = t0 + t1;
            r597[a0] = t2[10:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 - 20;
          a2 = a2 - 20;
        end
        state <= 671;
      end
      671: begin  // instr 477 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 20; c1 = c1 + 1) begin
            t0 = $signed(r597[a1]);
            t1 = t0 >>> 1;
            r598[a0] = t1[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 20;
        end
        state <= 672;
      end
      672: begin  // instr 478 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 20; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r598[a1]);
              r599[a0] = t0[9:0];
              a0 = a0 + 1;
            end
            a1 = a1 + 1;
          end
          a1 = a1 - 20;
        end
        state <= 673;
      end
      673: begin  // instr 479 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 20; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r591[a1]);
              t1 = $signed(r599[a2]);
              t2 = t0 - t1;
              r600[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a2 = a2 + 1;
          end
          a1 = a1 - 120;
          a2 = a2 - 20;
        end
        state <= 674;
      end
      674: begin  // instr 480 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 20; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r600[a1]);
              t1 = $signed(rom9_lit[a2]);
              t2 = (t0 < t1) ? t1 : t0;
              r601[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 120;
        end
        state <= 675;
      end
      675: begin  // instr 481 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 20; c0 = c0 + 1) begin
          r602[a0] = t0[13:0];
          a0 = a0 + 1;
        end
        state <= 676;
      end
      676: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 20; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r602[a0]);
              t1 = $signed(r601[a1]);
              t2 = t0 + t1;
              r602[a0] = t2[13:0];
              a1 = a1 + 1;
            end
            a0 = a0 + 1;
          end
        end
        state <= 677;
      end
      677: begin  // instr 482 neg
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 20; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r591[a1]);
              t1 = 0 - t0;
              r603[a0] = t1[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 120;
        end
        state <= 678;
      end
      678: begin  // instr 483 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 20; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r598[a1]);
              r604[a0] = t0[9:0];
              a0 = a0 + 1;
            end
            a1 = a1 + 1;
          end
          a1 = a1 - 20;
        end
        state <= 679;
      end
      679: begin  // instr 484 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 20; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r603[a1]);
              t1 = $signed(r604[a2]);
              t2 = t0 - t1;
              r605[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a2 = a2 + 1;
          end
          a1 = a1 - 120;
          a2 = a2 - 20;
        end
        state <= 680;
      end
      680: begin  // instr 485 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 20; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r605[a1]);
              t1 = $signed(rom9_lit[a2]);
              t2 = (t0 < t1) ? t1 : t0;
              r606[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 120;
        end
        state <= 681;
      end
      681: begin  // instr 486 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 20; c0 = c0 + 1) begin
          r607[a0] = t0[13:0];
          a0 = a0 + 1;
        end
        state <= 682;
      end
      682: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 20; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r607[a0]);
              t1 = $signed(r606[a1]);
              t2 = t0 + t1;
              r607[a0] = t2[13:0];
              a1 = a1 + 1;
            end
            a0 = a0 + 1;
          end
        end
        state <= 683;
      end
      683: begin  // instr 487 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 20; c1 = c1 + 1) begin
            t0 = $signed(r602[a1]);
            t1 = $signed(r607[a2]);
            t2 = t0 + t1;
            r608[a0] = t2[14:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 - 20;
          a2 = a2 - 20;
        end
        state <= 684;
      end
      684: begin  // instr 488 gt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 20; c1 = c1 + 1) begin
            t0 = $signed(r608[a1]);
            t1 = $signed(r592[a2]);
            t2 = (t0 > t1) ? 1 : 0;
            r609[a0] = (t2 != 0);
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 20;
        end
        state <= 685;
      end
      685: begin  // instr 489 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 20; c1 = c1 + 1) begin
            t0 = r609[a1];
            t1 = $signed(r594[a2]);
            t2 = $signed(r598[a3]);
            t3 = (t0 != 0) ? t2 : t1;
            r610[a0] = t3[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
            a3 = a3 + 1;
          end
          a1 = a1 - 20;
          a2 = a2 - 20;
          a3 = a3 - 20;
        end
        state <= 686;
      end
      686: begin  // instr 490 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 20; c1 = c1 + 1) begin
            t0 = r609[a1];
            t1 = $signed(r598[a2]);
            t2 = $signed(r595[a3]);
            t3 = (t0 != 0) ? t2 : t1;
            r611[a0] = t3[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
            a3 = a3 + 1;
          end
          a1 = a1 - 20;
          a2 = a2 - 20;
          a3 = a3 - 20;
        end
        state <= 687;
      end
      687: begin  // loop11.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r596[a1]);
          r593[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 688;
      end
      688: begin  // loop11.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 20; c0 = c0 + 1) begin
          t0 = $signed(r610[a1]);
          r594[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 689;
      end
      689: begin  // loop11.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 20; c0 = c0 + 1) begin
          t0 = $signed(r611[a1]);
          r595[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 690;
      end
      690: begin  // loop11.adv
        k11 = k11 + 1;
        state <= 668;
      end
      691: begin  // loop11.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r593[a1]);
          r612[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 692;
      end
      692: begin  // loop11.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 20; c0 = c0 + 1) begin
          t0 = $signed(r594[a1]);
          r613[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 693;
      end
      693: begin  // loop11.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 20; c0 = c0 + 1) begin
          t0 = $signed(r595[a1]);
          r614[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 694;
      end
      694: begin  // instr 491 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 20; c1 = c1 + 1) begin
            t0 = $signed(r587[a1]);
            t1 = $signed(r614[a2]);
            t2 = t0 - t1;
            r615[a0] = t2[10:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 - 20;
          a2 = a2 - 20;
        end
        state <= 695;
      end
      695: begin  // instr 492 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 20; c1 = c1 + 1) begin
            t0 = $signed(r615[a1]);
            t1 = t0 >>> 1;
            r616[a0] = t1[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 20;
        end
        state <= 696;
      end
      696: begin  // instr 493 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom15_lit[a1]);
        t1 = t0;
        r617[a0] = t1[7:0];
        state <= 697;
      end
      697: begin  // instr 494 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 20; c1 = c1 + 1) begin
            t0 = $signed(r617[a1]);
            t1 = $signed(r616[a2]);
            t2 = (t0 < t1) ? t1 : t0;
            r618[a0] = t2[9:0];
            a0 = a0 + 1;
            a2 = a2 + 1;
          end
          a2 = a2 - 20;
        end
        state <= 698;
      end
      698: begin  // instr 495 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom16_lit[a1]);
        t1 = t0;
        r619[a0] = t1[7:0];
        state <= 699;
      end
      699: begin  // instr 496 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 20; c1 = c1 + 1) begin
            t0 = $signed(r619[a1]);
            t1 = $signed(r618[a2]);
            t2 = (t1 < t0) ? t1 : t0;
            r620[a0] = t2[7:0];
            a0 = a0 + 1;
            a2 = a2 + 1;
          end
          a2 = a2 - 20;
        end
        state <= 700;
      end
      700: begin  // instr 497 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r427[a1]);
          t1 = $signed(r528[a2]);
          t2 = t0 - t1;
          r621[a0] = t2[6:0];
          a0 = a0 + 1;
        end
        state <= 701;
      end
      701: begin  // instr 498 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r621[a1]);
          t1 = $signed(rom8_lit[a2]);
          t2 = t0 + t1;
          r622[a0] = t2[6:0];
          a0 = a0 + 1;
        end
        state <= 702;
      end
      702: begin  // instr 499 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r622[a1]);
          t1 = $signed(rom9_lit[a2]);
          t2 = (t0 < t1) ? t1 : t0;
          r623[a0] = t2[6:0];
          a0 = a0 + 1;
        end
        state <= 703;
      end
      703: begin  // instr 500 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r623[a1]);
          t1 = t0 >>> 1;
          r624[a0] = t1[5:0];
          a0 = a0 + 1;
        end
        state <= 704;
      end
      704: begin  // instr 501 concat
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 15; c1 = c1 + 1) begin
            t0 = $signed(r3[a1]);
            r625[a0] = t0[7:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a0 = a0 + 20;
        end
        state <= 705;
      end
      705: begin  // concat
        a0 = 15;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 20; c1 = c1 + 1) begin
            t0 = $signed(r620[a1]);
            r625[a0] = t0[7:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a0 = a0 + 15;
        end
        state <= 706;
      end
      706: begin  // instr 502 shl
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 35; c1 = c1 + 1) begin
            t0 = $signed(r625[a1]);
            t1 = t0 << 1;
            r626[a0] = t1[8:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 35;
        end
        state <= 707;
      end
      707: begin  // instr 503 mov
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(rom0_c[a1]);
            t1 = t0;
            r627[a0] = t1[5:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
        end
        state <= 708;
      end
      708: begin  // instr 504 rev
        a0 = 0;
        a1 = 15;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(r627[a1]);
            r628[a0] = t0[5:0];
            a0 = a0 + 1;
            a1 = a1 - 1;
          end
          a1 = a1 + 32;
        end
        state <= 709;
      end
      709: begin  // instr 505 reshape
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 80; c0 = c0 + 1) begin
          t0 = $signed(r628[a1]);
          r629[a0] = t0[5:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 710;
      end
      710: begin  // instr 506 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 20; c0 = c0 + 1) begin
          t0 = a1;
          r630[a0] = t0[5:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 711;
      end
      711: begin  // instr 507 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 20; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            t0 = $signed(r630[a1]);
            r631[a0] = t0[5:0];
            a0 = a0 + 1;
          end
          a1 = a1 + 1;
        end
        state <= 712;
      end
      712: begin  // instr 508 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 16; c0 = c0 + 1) begin
          t0 = a1;
          r632[a0] = t0[4:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 713;
      end
      713: begin  // instr 509 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(r632[a1]);
            r633[a0] = t0[4:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 16;
        end
        state <= 714;
      end
      714: begin  // instr 510 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 20; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(r631[a1]);
            t1 = $signed(r633[a2]);
            t2 = t0 + t1;
            r634[a0] = t2[6:0];
            a0 = a0 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 + 1;
          a2 = a2 - 16;
        end
        state <= 715;
      end
      715: begin  // instr 511 lt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 20; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(r634[a1]);
            t1 = $signed(rom9_lit[a2]);
            t2 = (t0 < t1) ? 1 : 0;
            r635[a0] = (t2 != 0);
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
        end
        state <= 716;
      end
      716: begin  // instr 512 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 20; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(r634[a1]);
            t1 = $signed(rom22_lit[a2]);
            t2 = t0 + t1;
            r637[a0] = t2[7:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
        end
        state <= 717;
      end
      717: begin  // instr 513 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 20; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = r635[a1];
            t1 = $signed(r634[a2]);
            t2 = $signed(r637[a3]);
            t3 = (t0 != 0) ? t2 : t1;
            r638[a0] = t3[6:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
            a3 = a3 + 1;
          end
        end
        state <= 718;
      end
      718: begin  // instr 514 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 20; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r638[a1]);
              r639[a0] = t0[6:0];
              a0 = a0 + 1;
            end
            a1 = a1 + 1;
          end
        end
        state <= 719;
      end
      719: begin  // instr 515 gather
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 20; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 16; c2 = c2 + 1) begin
              t9 = 0;
              t0 = $signed(r639[a2]);
              t1 = (t0 < 0) ? 0 : t0;
              t1 = (t1 > 34) ? 34 : t1;
              t2 = t1;
              t9 = t9 + t2;
              t3 = $signed(r626[a1 + t9]);
              r640[a0] = t3[8:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a1 = a1 + 35;
          a2 = a2 - 320;
        end
        state <= 720;
      end
      720: begin  // instr 516 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 20; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r640[a1]);
                r641[a0] = t0[8:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 320;
          end
        end
        state <= 721;
      end
      721: begin  // instr 517 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 20; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r629[a1]);
                t1 = $signed(r641[a2]);
                t2 = t0 + t1;
                r642[a0] = t2[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
                a2 = a2 + 1;
              end
              a1 = a1 - 16;
            end
            a2 = a2 - 320;
          end
          a1 = a1 + 16;
        end
        state <= 722;
      end
      722: begin  // instr 518 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom11_lit[a1]);
        t1 = t0;
        r643[a0] = t1[9:0];
        state <= 723;
      end
      723: begin  // instr 519 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 20; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r643[a1]);
                t1 = $signed(r642[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r644[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 320;
          end
          a2 = a2 + 320;
        end
        state <= 724;
      end
      724: begin  // instr 520 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom12_lit[a1]);
        t1 = t0;
        r645[a0] = t1[9:0];
        state <= 725;
      end
      725: begin  // instr 521 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 20; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r645[a1]);
                t1 = $signed(r644[a2]);
                t2 = (t1 < t0) ? t1 : t0;
                r646[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 320;
          end
          a2 = a2 + 320;
        end
        state <= 726;
      end
      726: begin  // instr 522 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 20; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r629[a1]);
                t1 = $signed(r641[a2]);
                t2 = t0 - t1;
                r647[a0] = t2[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
                a2 = a2 + 1;
              end
              a1 = a1 - 16;
            end
            a2 = a2 - 320;
          end
          a1 = a1 + 16;
        end
        state <= 727;
      end
      727: begin  // instr 523 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom11_lit[a1]);
        t1 = t0;
        r648[a0] = t1[9:0];
        state <= 728;
      end
      728: begin  // instr 524 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 20; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r648[a1]);
                t1 = $signed(r647[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r649[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 320;
          end
          a2 = a2 + 320;
        end
        state <= 729;
      end
      729: begin  // instr 525 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom12_lit[a1]);
        t1 = t0;
        r650[a0] = t1[9:0];
        state <= 730;
      end
      730: begin  // instr 526 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 20; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r650[a1]);
                t1 = $signed(r649[a2]);
                t2 = (t1 < t0) ? t1 : t0;
                r651[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 320;
          end
          a2 = a2 + 320;
        end
        state <= 731;
      end
      731: begin  // instr 527 abs
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 20; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r646[a1]);
                t1 = (t0 < 0) ? (0 - t0) : t0;
                r652[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 320;
          end
          a1 = a1 + 320;
        end
        state <= 732;
      end
      732: begin  // instr 528 reduce_max
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 100; c0 = c0 + 1) begin
          r653[a0] = t0[9:0];
          a0 = a0 + 1;
        end
        state <= 733;
      end
      733: begin  // reduce.max.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 20; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r653[a0]);
                t1 = $signed(r652[a1]);
                t2 = (t0 < t1) ? t1 : t0;
                r653[a0] = t2[9:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 734;
      end
      734: begin  // instr 529 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 20; c2 = c2 + 1) begin
              t0 = $signed(r653[a1]);
              t1 = $signed(rom13_lit[a2]);
              t2 = t0 - t1;
              r654[a0] = t2[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 20;
          end
          a1 = a1 + 20;
        end
        state <= 735;
      end
      735: begin  // instr 530 loop
        k12 = 0;
        state <= 736;
      end
      736: begin  // loop12.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1600; c0 = c0 + 1) begin
          t0 = $signed(r646[a1]);
          r655[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 737;
      end
      737: begin  // loop12.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom13_lit[a1]);
          r656[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 738;
      end
      738: begin  // loop12.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom9_lit[a1]);
          r657[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 739;
      end
      739: begin  // loop12.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 100; c0 = c0 + 1) begin
          t0 = $signed(r654[a1]);
          r658[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 740;
      end
      740: begin  // loop12.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 100; c0 = c0 + 1) begin
          t0 = $signed(r653[a1]);
          r659[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 741;
      end
      741: begin  // loop12.head
        if (k12 == 12) state <= 764;
        else state <= 742;
      end
      742: begin  // instr 531 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r657[a1]);
        t1 = $signed(rom8_lit[a2]);
        t2 = t0 + t1;
        r660[a0] = t2[4:0];
        state <= 743;
      end
      743: begin  // instr 532 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 20; c2 = c2 + 1) begin
              t0 = $signed(r658[a1]);
              t1 = $signed(r659[a2]);
              t2 = t0 + t1;
              r661[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 20;
            a2 = a2 - 20;
          end
          a1 = a1 + 20;
          a2 = a2 + 20;
        end
        state <= 744;
      end
      744: begin  // instr 533 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 20; c2 = c2 + 1) begin
              t0 = $signed(r661[a1]);
              t1 = t0 >>> 1;
              r662[a0] = t1[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 20;
          end
          a1 = a1 + 20;
        end
        state <= 745;
      end
      745: begin  // instr 534 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 20; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r662[a1]);
                r663[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 20;
          end
          a1 = a1 + 20;
        end
        state <= 746;
      end
      746: begin  // instr 535 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 20; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r655[a1]);
                t1 = $signed(r663[a2]);
                t2 = t0 - t1;
                r664[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 320;
            a2 = a2 - 20;
          end
          a1 = a1 + 320;
          a2 = a2 + 20;
        end
        state <= 747;
      end
      747: begin  // instr 536 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 20; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r664[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r665[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 320;
          end
          a1 = a1 + 320;
        end
        state <= 748;
      end
      748: begin  // instr 537 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 100; c0 = c0 + 1) begin
          r666[a0] = t0[14:0];
          a0 = a0 + 1;
        end
        state <= 749;
      end
      749: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 20; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r666[a0]);
                t1 = $signed(r665[a1]);
                t2 = t0 + t1;
                r666[a0] = t2[14:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 750;
      end
      750: begin  // instr 538 neg
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 20; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r655[a1]);
                t1 = 0 - t0;
                r667[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 320;
          end
          a1 = a1 + 320;
        end
        state <= 751;
      end
      751: begin  // instr 539 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 20; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r662[a1]);
                r668[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 20;
          end
          a1 = a1 + 20;
        end
        state <= 752;
      end
      752: begin  // instr 540 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 20; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r667[a1]);
                t1 = $signed(r668[a2]);
                t2 = t0 - t1;
                r669[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 320;
            a2 = a2 - 20;
          end
          a1 = a1 + 320;
          a2 = a2 + 20;
        end
        state <= 753;
      end
      753: begin  // instr 541 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 20; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r669[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r670[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 320;
          end
          a1 = a1 + 320;
        end
        state <= 754;
      end
      754: begin  // instr 542 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 100; c0 = c0 + 1) begin
          r671[a0] = t0[14:0];
          a0 = a0 + 1;
        end
        state <= 755;
      end
      755: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 20; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r671[a0]);
                t1 = $signed(r670[a1]);
                t2 = t0 + t1;
                r671[a0] = t2[14:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 756;
      end
      756: begin  // instr 543 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 20; c2 = c2 + 1) begin
              t0 = $signed(r666[a1]);
              t1 = $signed(r671[a2]);
              t2 = t0 + t1;
              r672[a0] = t2[15:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 20;
            a2 = a2 - 20;
          end
          a1 = a1 + 20;
          a2 = a2 + 20;
        end
        state <= 757;
      end
      757: begin  // instr 544 gt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 20; c2 = c2 + 1) begin
              t0 = $signed(r672[a1]);
              t1 = $signed(r656[a2]);
              t2 = (t0 > t1) ? 1 : 0;
              r673[a0] = (t2 != 0);
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 20;
          end
          a1 = a1 + 20;
        end
        state <= 758;
      end
      758: begin  // instr 545 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 20; c2 = c2 + 1) begin
              t0 = r673[a1];
              t1 = $signed(r658[a2]);
              t2 = $signed(r662[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r674[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 20;
            a2 = a2 - 20;
            a3 = a3 - 20;
          end
          a1 = a1 + 20;
          a2 = a2 + 20;
          a3 = a3 + 20;
        end
        state <= 759;
      end
      759: begin  // instr 546 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 20; c2 = c2 + 1) begin
              t0 = r673[a1];
              t1 = $signed(r662[a2]);
              t2 = $signed(r659[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r675[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 20;
            a2 = a2 - 20;
            a3 = a3 - 20;
          end
          a1 = a1 + 20;
          a2 = a2 + 20;
          a3 = a3 + 20;
        end
        state <= 760;
      end
      760: begin  // loop12.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r660[a1]);
          r657[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 761;
      end
      761: begin  // loop12.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 100; c0 = c0 + 1) begin
          t0 = $signed(r674[a1]);
          r658[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 762;
      end
      762: begin  // loop12.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 100; c0 = c0 + 1) begin
          t0 = $signed(r675[a1]);
          r659[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 763;
      end
      763: begin  // loop12.adv
        k12 = k12 + 1;
        state <= 741;
      end
      764: begin  // loop12.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r657[a1]);
          r676[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 765;
      end
      765: begin  // loop12.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 100; c0 = c0 + 1) begin
          t0 = $signed(r658[a1]);
          r677[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 766;
      end
      766: begin  // loop12.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 100; c0 = c0 + 1) begin
          t0 = $signed(r659[a1]);
          r678[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 767;
      end
      767: begin  // instr 547 abs
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 20; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r651[a1]);
                t1 = (t0 < 0) ? (0 - t0) : t0;
                r679[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 320;
          end
          a1 = a1 + 320;
        end
        state <= 768;
      end
      768: begin  // instr 548 reduce_max
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 100; c0 = c0 + 1) begin
          r680[a0] = t0[9:0];
          a0 = a0 + 1;
        end
        state <= 769;
      end
      769: begin  // reduce.max.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 20; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r680[a0]);
                t1 = $signed(r679[a1]);
                t2 = (t0 < t1) ? t1 : t0;
                r680[a0] = t2[9:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 770;
      end
      770: begin  // instr 549 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 20; c2 = c2 + 1) begin
              t0 = $signed(r680[a1]);
              t1 = $signed(rom13_lit[a2]);
              t2 = t0 - t1;
              r681[a0] = t2[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 20;
          end
          a1 = a1 + 20;
        end
        state <= 771;
      end
      771: begin  // instr 550 loop
        k13 = 0;
        state <= 772;
      end
      772: begin  // loop13.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1600; c0 = c0 + 1) begin
          t0 = $signed(r651[a1]);
          r682[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 773;
      end
      773: begin  // loop13.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom13_lit[a1]);
          r683[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 774;
      end
      774: begin  // loop13.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom9_lit[a1]);
          r684[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 775;
      end
      775: begin  // loop13.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 100; c0 = c0 + 1) begin
          t0 = $signed(r681[a1]);
          r685[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 776;
      end
      776: begin  // loop13.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 100; c0 = c0 + 1) begin
          t0 = $signed(r680[a1]);
          r686[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 777;
      end
      777: begin  // loop13.head
        if (k13 == 12) state <= 800;
        else state <= 778;
      end
      778: begin  // instr 551 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r684[a1]);
        t1 = $signed(rom8_lit[a2]);
        t2 = t0 + t1;
        r687[a0] = t2[4:0];
        state <= 779;
      end
      779: begin  // instr 552 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 20; c2 = c2 + 1) begin
              t0 = $signed(r685[a1]);
              t1 = $signed(r686[a2]);
              t2 = t0 + t1;
              r688[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 20;
            a2 = a2 - 20;
          end
          a1 = a1 + 20;
          a2 = a2 + 20;
        end
        state <= 780;
      end
      780: begin  // instr 553 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 20; c2 = c2 + 1) begin
              t0 = $signed(r688[a1]);
              t1 = t0 >>> 1;
              r689[a0] = t1[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 20;
          end
          a1 = a1 + 20;
        end
        state <= 781;
      end
      781: begin  // instr 554 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 20; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r689[a1]);
                r690[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 20;
          end
          a1 = a1 + 20;
        end
        state <= 782;
      end
      782: begin  // instr 555 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 20; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r682[a1]);
                t1 = $signed(r690[a2]);
                t2 = t0 - t1;
                r691[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 320;
            a2 = a2 - 20;
          end
          a1 = a1 + 320;
          a2 = a2 + 20;
        end
        state <= 783;
      end
      783: begin  // instr 556 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 20; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r691[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r692[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 320;
          end
          a1 = a1 + 320;
        end
        state <= 784;
      end
      784: begin  // instr 557 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 100; c0 = c0 + 1) begin
          r693[a0] = t0[14:0];
          a0 = a0 + 1;
        end
        state <= 785;
      end
      785: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 20; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r693[a0]);
                t1 = $signed(r692[a1]);
                t2 = t0 + t1;
                r693[a0] = t2[14:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 786;
      end
      786: begin  // instr 558 neg
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 20; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r682[a1]);
                t1 = 0 - t0;
                r694[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 320;
          end
          a1 = a1 + 320;
        end
        state <= 787;
      end
      787: begin  // instr 559 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 20; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r689[a1]);
                r695[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 20;
          end
          a1 = a1 + 20;
        end
        state <= 788;
      end
      788: begin  // instr 560 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 20; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r694[a1]);
                t1 = $signed(r695[a2]);
                t2 = t0 - t1;
                r696[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 320;
            a2 = a2 - 20;
          end
          a1 = a1 + 320;
          a2 = a2 + 20;
        end
        state <= 789;
      end
      789: begin  // instr 561 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 20; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r696[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r697[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 320;
          end
          a1 = a1 + 320;
        end
        state <= 790;
      end
      790: begin  // instr 562 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 100; c0 = c0 + 1) begin
          r698[a0] = t0[14:0];
          a0 = a0 + 1;
        end
        state <= 791;
      end
      791: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 20; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r698[a0]);
                t1 = $signed(r697[a1]);
                t2 = t0 + t1;
                r698[a0] = t2[14:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 792;
      end
      792: begin  // instr 563 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 20; c2 = c2 + 1) begin
              t0 = $signed(r693[a1]);
              t1 = $signed(r698[a2]);
              t2 = t0 + t1;
              r699[a0] = t2[15:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 20;
            a2 = a2 - 20;
          end
          a1 = a1 + 20;
          a2 = a2 + 20;
        end
        state <= 793;
      end
      793: begin  // instr 564 gt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 20; c2 = c2 + 1) begin
              t0 = $signed(r699[a1]);
              t1 = $signed(r683[a2]);
              t2 = (t0 > t1) ? 1 : 0;
              r700[a0] = (t2 != 0);
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 20;
          end
          a1 = a1 + 20;
        end
        state <= 794;
      end
      794: begin  // instr 565 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 20; c2 = c2 + 1) begin
              t0 = r700[a1];
              t1 = $signed(r685[a2]);
              t2 = $signed(r689[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r701[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 20;
            a2 = a2 - 20;
            a3 = a3 - 20;
          end
          a1 = a1 + 20;
          a2 = a2 + 20;
          a3 = a3 + 20;
        end
        state <= 795;
      end
      795: begin  // instr 566 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 20; c2 = c2 + 1) begin
              t0 = r700[a1];
              t1 = $signed(r689[a2]);
              t2 = $signed(r686[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r702[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 20;
            a2 = a2 - 20;
            a3 = a3 - 20;
          end
          a1 = a1 + 20;
          a2 = a2 + 20;
          a3 = a3 + 20;
        end
        state <= 796;
      end
      796: begin  // loop13.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r687[a1]);
          r684[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 797;
      end
      797: begin  // loop13.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 100; c0 = c0 + 1) begin
          t0 = $signed(r701[a1]);
          r685[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 798;
      end
      798: begin  // loop13.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 100; c0 = c0 + 1) begin
          t0 = $signed(r702[a1]);
          r686[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 799;
      end
      799: begin  // loop13.adv
        k13 = k13 + 1;
        state <= 777;
      end
      800: begin  // loop13.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r684[a1]);
          r703[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 801;
      end
      801: begin  // loop13.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 100; c0 = c0 + 1) begin
          t0 = $signed(r685[a1]);
          r704[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 802;
      end
      802: begin  // loop13.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 100; c0 = c0 + 1) begin
          t0 = $signed(r686[a1]);
          r705[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 803;
      end
      803: begin  // instr 567 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 20; c2 = c2 + 1) begin
              t0 = $signed(r678[a1]);
              t1 = $signed(r705[a2]);
              t2 = t0 - t1;
              r706[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 20;
            a2 = a2 - 20;
          end
          a1 = a1 + 20;
          a2 = a2 + 20;
        end
        state <= 804;
      end
      804: begin  // instr 568 transpose
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 20; c2 = c2 + 1) begin
              t0 = $signed(r706[a1]);
              r707[a0] = t0[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 80;
        end
        state <= 805;
      end
      805: begin  // instr 569 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            t0 = $signed(r624[a1]);
            r708[a0] = t0[5:0];
            a0 = a0 + 1;
          end
        end
        state <= 806;
      end
      806: begin  // instr 570 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 20; c2 = c2 + 1) begin
              t0 = $signed(r707[a1]);
              t1 = $signed(rom9_lit[a2]);
              t2 = (t0 < t1) ? t1 : t0;
              r709[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 100;
        end
        state <= 807;
      end
      807: begin  // instr 571 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 20; c2 = c2 + 1) begin
              t0 = a1;
              r710[a0] = t0[5:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 20;
          end
        end
        state <= 808;
      end
      808: begin  // instr 572 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r708[a1]);
              r711[a0] = t0[5:0];
              a0 = a0 + 1;
            end
          end
        end
        state <= 809;
      end
      809: begin  // instr 573 lt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 20; c2 = c2 + 1) begin
              t0 = $signed(r710[a1]);
              t1 = $signed(r711[a2]);
              t2 = (t0 < t1) ? 1 : 0;
              r712[a0] = (t2 != 0);
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 100;
        end
        state <= 810;
      end
      810: begin  // instr 574 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom9_lit[a1]);
        t1 = t0;
        r713[a0] = t1[0:0];
        state <= 811;
      end
      811: begin  // instr 575 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 20; c2 = c2 + 1) begin
              t0 = $signed(r713[a1]);
              r714[a0] = t0[0:0];
              a0 = a0 + 1;
            end
          end
        end
        state <= 812;
      end
      812: begin  // instr 576 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 20; c2 = c2 + 1) begin
              t0 = r712[a1];
              t1 = $signed(r714[a2]);
              t2 = $signed(r709[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r715[a0] = t3[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
          end
          a1 = a1 - 100;
          a2 = a2 - 100;
          a3 = a3 - 100;
        end
        state <= 813;
      end
      813: begin  // instr 577 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          r716[a0] = t0[14:0];
          a0 = a0 + 1;
        end
        state <= 814;
      end
      814: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 20; c2 = c2 + 1) begin
              t0 = $signed(r716[a0]);
              t1 = $signed(r715[a1]);
              t2 = t0 + t1;
              r716[a0] = t2[14:0];
              a1 = a1 + 1;
            end
            a0 = a0 + 1;
          end
        end
        state <= 815;
      end
      815: begin  // instr 578 shl
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            t0 = $signed(r716[a1]);
            t1 = t0 << 3;
            r718[a0] = t1[17:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 5;
        end
        state <= 816;
      end
      816: begin  // instr 579 lt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r624[a1]);
          t1 = $signed(rom9_lit[a2]);
          t2 = (t0 < t1) ? 1 : 0;
          r719[a0] = (t2 != 0);
          a0 = a0 + 1;
        end
        state <= 817;
      end
      817: begin  // instr 580 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r624[a1]);
          t1 = $signed(rom22_lit[a2]);
          t2 = t0 + t1;
          r720[a0] = t2[6:0];
          a0 = a0 + 1;
        end
        state <= 818;
      end
      818: begin  // instr 581 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = r719[a1];
          t1 = $signed(r624[a2]);
          t2 = $signed(r720[a3]);
          t3 = (t0 != 0) ? t2 : t1;
          r721[a0] = t3[5:0];
          a0 = a0 + 1;
        end
        state <= 819;
      end
      819: begin  // instr 582 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            t0 = $signed(r721[a1]);
            r722[a0] = t0[5:0];
            a0 = a0 + 1;
          end
        end
        state <= 820;
      end
      820: begin  // instr 583 gather
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 15; c1 = c1 + 1) begin
            t9 = 0;
            t0 = $signed(r722[a2]);
            t1 = (t0 < 0) ? 0 : t0;
            t1 = (t1 > 20) ? 20 : t1;
            t2 = t1;
            t9 = t9 + t2;
            t3 = $signed(r625[a1 + t9]);
            r723[a0] = t3[7:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 + 20;
          a2 = a2 + 1;
        end
        state <= 821;
      end
      821: begin  // instr 584 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r9[a1]);
          t1 = $signed(r624[a2]);
          t2 = t0 + t1;
          r724[a0] = t2;
          a0 = a0 + 1;
        end
        state <= 822;
      end
      822: begin  // instr 585 and
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r9[a1]);
          t1 = $signed(rom8_lit[a2]);
          t2 = t0 & t1;
          r725[a0] = t2[1:0];
          a0 = a0 + 1;
        end
        state <= 823;
      end
      823: begin  // instr 586 slice
        a0 = 0;
        a1 = 10;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 25; c1 = c1 + 1) begin
            t0 = $signed(r625[a1]);
            r726[a0] = t0[7:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 + 10;
        end
        state <= 824;
      end
      824: begin  // instr 587 shl
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 25; c1 = c1 + 1) begin
            t0 = $signed(r726[a1]);
            t1 = t0 << 1;
            r727[a0] = t1[8:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 25;
        end
        state <= 825;
      end
      825: begin  // instr 588 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom9_lit[a1]);
        t1 = t0;
        r728[a0] = t1[0:0];
        state <= 826;
      end
      826: begin  // instr 589 pad
        t0 = $signed(r728[0]);
        a0 = 0;
        for (c0 = 0; c0 < 26; c0 = c0 + 1) begin
          r729[a0] = t0[8:0];
          a0 = a0 + 1;
        end
        state <= 827;
      end
      827: begin  // pad.scatter
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 25; c1 = c1 + 1) begin
            t1 = $signed(r727[a1]);
            r729[a0] = t1[8:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a0 = a0 + 1;
        end
        state <= 828;
      end
      828: begin  // instr 590 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          t0 = a1;
          r730[a0] = t0[4:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 829;
      end
      829: begin  // instr 591 shl
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          t0 = $signed(r730[a1]);
          t1 = t0 << 1;
          r731[a0] = t1[5:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 830;
      end
      830: begin  // instr 592 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            t0 = $signed(r731[a1]);
            r732[a0] = t0[5:0];
            a0 = a0 + 1;
          end
          a1 = a1 + 1;
        end
        state <= 831;
      end
      831: begin  // instr 593 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 6; c0 = c0 + 1) begin
          t0 = a1;
          r733[a0] = t0[3:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 832;
      end
      832: begin  // instr 594 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 6; c1 = c1 + 1) begin
            t0 = $signed(r733[a1]);
            r734[a0] = t0[3:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 6;
        end
        state <= 833;
      end
      833: begin  // instr 595 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 6; c1 = c1 + 1) begin
            t0 = $signed(r732[a1]);
            t1 = $signed(r734[a2]);
            t2 = t0 + t1;
            r735[a0] = t2[5:0];
            a0 = a0 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 + 1;
          a2 = a2 - 6;
        end
        state <= 834;
      end
      834: begin  // instr 596 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r735[a1]);
              r736[a0] = t0[5:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 60;
        end
        state <= 835;
      end
      835: begin  // instr 597 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r725[a1]);
              r737[a0] = t0[1:0];
              a0 = a0 + 1;
            end
          end
        end
        state <= 836;
      end
      836: begin  // instr 598 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r737[a1]);
              t1 = $signed(r736[a2]);
              t2 = t0 + t1;
              r738[a0] = t2[5:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a2 = a2 - 60;
        end
        state <= 837;
      end
      837: begin  // instr 599 lt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r738[a1]);
              t1 = $signed(rom9_lit[a2]);
              t2 = (t0 < t1) ? 1 : 0;
              r739[a0] = (t2 != 0);
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 60;
        end
        state <= 838;
      end
      838: begin  // instr 600 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r738[a1]);
              t1 = $signed(rom24_lit[a2]);
              t2 = t0 + t1;
              r741[a0] = t2[6:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 60;
        end
        state <= 839;
      end
      839: begin  // instr 601 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = r739[a1];
              t1 = $signed(r738[a2]);
              t2 = $signed(r741[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r742[a0] = t3[5:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
          end
          a1 = a1 - 60;
          a2 = a2 - 60;
          a3 = a3 - 60;
        end
        state <= 840;
      end
      840: begin  // instr 602 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r742[a1]);
                r743[a0] = t0[5:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 60;
        end
        state <= 841;
      end
      841: begin  // instr 603 gather
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t9 = 0;
              t0 = $signed(r743[a2]);
              t1 = (t0 < 0) ? 0 : t0;
              t1 = (t1 > 25) ? 25 : t1;
              t2 = t1;
              t9 = t9 + t2;
              t3 = $signed(r729[a1 + t9]);
              r744[a0] = t3[8:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a1 = a1 + 26;
        end
        state <= 842;
      end
      842: begin  // instr 604 mov
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 6; c0 = c0 + 1) begin
          t0 = $signed(rom1_c[a1]);
          t1 = t0;
          r745[a0] = t1[6:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 843;
      end
      843: begin  // instr 605 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r745[a1]);
              r746[a0] = t0[6:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 6;
          end
        end
        state <= 844;
      end
      844: begin  // instr 606 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r746[a1]);
              t1 = $signed(r744[a2]);
              t2 = t0 + t1;
              r747[a0] = t2[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 6;
          end
          a2 = a2 - 60;
        end
        state <= 845;
      end
      845: begin  // instr 607 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom11_lit[a1]);
        t1 = t0;
        r748[a0] = t1[9:0];
        state <= 846;
      end
      846: begin  // instr 608 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r748[a1]);
              t1 = $signed(r747[a2]);
              t2 = (t0 < t1) ? t1 : t0;
              r749[a0] = t2[9:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a2 = a2 - 60;
        end
        state <= 847;
      end
      847: begin  // instr 609 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom12_lit[a1]);
        t1 = t0;
        r750[a0] = t1[9:0];
        state <= 848;
      end
      848: begin  // instr 610 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r750[a1]);
              t1 = $signed(r749[a2]);
              t2 = (t1 < t0) ? t1 : t0;
              r751[a0] = t2[9:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a2 = a2 - 60;
        end
        state <= 849;
      end
      849: begin  // instr 611 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r745[a1]);
              r752[a0] = t0[6:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 6;
          end
        end
        state <= 850;
      end
      850: begin  // instr 612 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r752[a1]);
              t1 = $signed(r744[a2]);
              t2 = t0 - t1;
              r753[a0] = t2[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 6;
          end
          a2 = a2 - 60;
        end
        state <= 851;
      end
      851: begin  // instr 613 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom11_lit[a1]);
        t1 = t0;
        r754[a0] = t1[9:0];
        state <= 852;
      end
      852: begin  // instr 614 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r754[a1]);
              t1 = $signed(r753[a2]);
              t2 = (t0 < t1) ? t1 : t0;
              r755[a0] = t2[9:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a2 = a2 - 60;
        end
        state <= 853;
      end
      853: begin  // instr 615 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom12_lit[a1]);
        t1 = t0;
        r756[a0] = t1[9:0];
        state <= 854;
      end
      854: begin  // instr 616 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r756[a1]);
              t1 = $signed(r755[a2]);
              t2 = (t1 < t0) ? t1 : t0;
              r757[a0] = t2[9:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a2 = a2 - 60;
        end
        state <= 855;
      end
      855: begin  // instr 617 abs
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r751[a1]);
              t1 = (t0 < 0) ? (0 - t0) : t0;
              r758[a0] = t1[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 60;
        end
        state <= 856;
      end
      856: begin  // instr 618 reduce_max
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          r759[a0] = t0[9:0];
          a0 = a0 + 1;
        end
        state <= 857;
      end
      857: begin  // reduce.max.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r759[a0]);
              t1 = $signed(r758[a1]);
              t2 = (t0 < t1) ? t1 : t0;
              r759[a0] = t2[9:0];
              a1 = a1 + 1;
            end
            a0 = a0 + 1;
          end
        end
        state <= 858;
      end
      858: begin  // instr 619 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = $signed(r759[a1]);
            t1 = $signed(rom13_lit[a2]);
            t2 = t0 - t1;
            r760[a0] = t2[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 10;
        end
        state <= 859;
      end
      859: begin  // instr 620 loop
        k14 = 0;
        state <= 860;
      end
      860: begin  // loop14.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 60; c0 = c0 + 1) begin
          t0 = $signed(r751[a1]);
          r761[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 861;
      end
      861: begin  // loop14.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom13_lit[a1]);
          r762[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 862;
      end
      862: begin  // loop14.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom9_lit[a1]);
          r763[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 863;
      end
      863: begin  // loop14.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          t0 = $signed(r760[a1]);
          r764[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 864;
      end
      864: begin  // loop14.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          t0 = $signed(r759[a1]);
          r765[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 865;
      end
      865: begin  // loop14.head
        if (k14 == 12) state <= 888;
        else state <= 866;
      end
      866: begin  // instr 621 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r763[a1]);
        t1 = $signed(rom8_lit[a2]);
        t2 = t0 + t1;
        r766[a0] = t2[4:0];
        state <= 867;
      end
      867: begin  // instr 622 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = $signed(r764[a1]);
            t1 = $signed(r765[a2]);
            t2 = t0 + t1;
            r767[a0] = t2[10:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 - 10;
          a2 = a2 - 10;
        end
        state <= 868;
      end
      868: begin  // instr 623 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = $signed(r767[a1]);
            t1 = t0 >>> 1;
            r768[a0] = t1[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 10;
        end
        state <= 869;
      end
      869: begin  // instr 624 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r768[a1]);
              r769[a0] = t0[9:0];
              a0 = a0 + 1;
            end
            a1 = a1 + 1;
          end
          a1 = a1 - 10;
        end
        state <= 870;
      end
      870: begin  // instr 625 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r761[a1]);
              t1 = $signed(r769[a2]);
              t2 = t0 - t1;
              r770[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a2 = a2 + 1;
          end
          a1 = a1 - 60;
          a2 = a2 - 10;
        end
        state <= 871;
      end
      871: begin  // instr 626 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r770[a1]);
              t1 = $signed(rom9_lit[a2]);
              t2 = (t0 < t1) ? t1 : t0;
              r771[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 60;
        end
        state <= 872;
      end
      872: begin  // instr 627 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          r772[a0] = t0[13:0];
          a0 = a0 + 1;
        end
        state <= 873;
      end
      873: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r772[a0]);
              t1 = $signed(r771[a1]);
              t2 = t0 + t1;
              r772[a0] = t2[13:0];
              a1 = a1 + 1;
            end
            a0 = a0 + 1;
          end
        end
        state <= 874;
      end
      874: begin  // instr 628 neg
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r761[a1]);
              t1 = 0 - t0;
              r773[a0] = t1[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 60;
        end
        state <= 875;
      end
      875: begin  // instr 629 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r768[a1]);
              r774[a0] = t0[9:0];
              a0 = a0 + 1;
            end
            a1 = a1 + 1;
          end
          a1 = a1 - 10;
        end
        state <= 876;
      end
      876: begin  // instr 630 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r773[a1]);
              t1 = $signed(r774[a2]);
              t2 = t0 - t1;
              r775[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a2 = a2 + 1;
          end
          a1 = a1 - 60;
          a2 = a2 - 10;
        end
        state <= 877;
      end
      877: begin  // instr 631 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r775[a1]);
              t1 = $signed(rom9_lit[a2]);
              t2 = (t0 < t1) ? t1 : t0;
              r776[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 60;
        end
        state <= 878;
      end
      878: begin  // instr 632 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          r777[a0] = t0[13:0];
          a0 = a0 + 1;
        end
        state <= 879;
      end
      879: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r777[a0]);
              t1 = $signed(r776[a1]);
              t2 = t0 + t1;
              r777[a0] = t2[13:0];
              a1 = a1 + 1;
            end
            a0 = a0 + 1;
          end
        end
        state <= 880;
      end
      880: begin  // instr 633 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = $signed(r772[a1]);
            t1 = $signed(r777[a2]);
            t2 = t0 + t1;
            r778[a0] = t2[14:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 - 10;
          a2 = a2 - 10;
        end
        state <= 881;
      end
      881: begin  // instr 634 gt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = $signed(r778[a1]);
            t1 = $signed(r762[a2]);
            t2 = (t0 > t1) ? 1 : 0;
            r779[a0] = (t2 != 0);
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 10;
        end
        state <= 882;
      end
      882: begin  // instr 635 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = r779[a1];
            t1 = $signed(r764[a2]);
            t2 = $signed(r768[a3]);
            t3 = (t0 != 0) ? t2 : t1;
            r780[a0] = t3[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
            a3 = a3 + 1;
          end
          a1 = a1 - 10;
          a2 = a2 - 10;
          a3 = a3 - 10;
        end
        state <= 883;
      end
      883: begin  // instr 636 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = r779[a1];
            t1 = $signed(r768[a2]);
            t2 = $signed(r765[a3]);
            t3 = (t0 != 0) ? t2 : t1;
            r781[a0] = t3[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
            a3 = a3 + 1;
          end
          a1 = a1 - 10;
          a2 = a2 - 10;
          a3 = a3 - 10;
        end
        state <= 884;
      end
      884: begin  // loop14.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r766[a1]);
          r763[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 885;
      end
      885: begin  // loop14.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          t0 = $signed(r780[a1]);
          r764[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 886;
      end
      886: begin  // loop14.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          t0 = $signed(r781[a1]);
          r765[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 887;
      end
      887: begin  // loop14.adv
        k14 = k14 + 1;
        state <= 865;
      end
      888: begin  // loop14.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r763[a1]);
          r782[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 889;
      end
      889: begin  // loop14.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          t0 = $signed(r764[a1]);
          r783[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 890;
      end
      890: begin  // loop14.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          t0 = $signed(r765[a1]);
          r784[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 891;
      end
      891: begin  // instr 637 abs
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r757[a1]);
              t1 = (t0 < 0) ? (0 - t0) : t0;
              r785[a0] = t1[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 60;
        end
        state <= 892;
      end
      892: begin  // instr 638 reduce_max
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          r786[a0] = t0[9:0];
          a0 = a0 + 1;
        end
        state <= 893;
      end
      893: begin  // reduce.max.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r786[a0]);
              t1 = $signed(r785[a1]);
              t2 = (t0 < t1) ? t1 : t0;
              r786[a0] = t2[9:0];
              a1 = a1 + 1;
            end
            a0 = a0 + 1;
          end
        end
        state <= 894;
      end
      894: begin  // instr 639 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = $signed(r786[a1]);
            t1 = $signed(rom13_lit[a2]);
            t2 = t0 - t1;
            r787[a0] = t2[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 10;
        end
        state <= 895;
      end
      895: begin  // instr 640 loop
        k15 = 0;
        state <= 896;
      end
      896: begin  // loop15.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 60; c0 = c0 + 1) begin
          t0 = $signed(r757[a1]);
          r788[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 897;
      end
      897: begin  // loop15.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom13_lit[a1]);
          r789[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 898;
      end
      898: begin  // loop15.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom9_lit[a1]);
          r790[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 899;
      end
      899: begin  // loop15.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          t0 = $signed(r787[a1]);
          r791[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 900;
      end
      900: begin  // loop15.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          t0 = $signed(r786[a1]);
          r792[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 901;
      end
      901: begin  // loop15.head
        if (k15 == 12) state <= 924;
        else state <= 902;
      end
      902: begin  // instr 641 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r790[a1]);
        t1 = $signed(rom8_lit[a2]);
        t2 = t0 + t1;
        r793[a0] = t2[4:0];
        state <= 903;
      end
      903: begin  // instr 642 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = $signed(r791[a1]);
            t1 = $signed(r792[a2]);
            t2 = t0 + t1;
            r794[a0] = t2[10:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 - 10;
          a2 = a2 - 10;
        end
        state <= 904;
      end
      904: begin  // instr 643 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = $signed(r794[a1]);
            t1 = t0 >>> 1;
            r795[a0] = t1[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 10;
        end
        state <= 905;
      end
      905: begin  // instr 644 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r795[a1]);
              r796[a0] = t0[9:0];
              a0 = a0 + 1;
            end
            a1 = a1 + 1;
          end
          a1 = a1 - 10;
        end
        state <= 906;
      end
      906: begin  // instr 645 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r788[a1]);
              t1 = $signed(r796[a2]);
              t2 = t0 - t1;
              r797[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a2 = a2 + 1;
          end
          a1 = a1 - 60;
          a2 = a2 - 10;
        end
        state <= 907;
      end
      907: begin  // instr 646 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r797[a1]);
              t1 = $signed(rom9_lit[a2]);
              t2 = (t0 < t1) ? t1 : t0;
              r798[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 60;
        end
        state <= 908;
      end
      908: begin  // instr 647 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          r799[a0] = t0[13:0];
          a0 = a0 + 1;
        end
        state <= 909;
      end
      909: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r799[a0]);
              t1 = $signed(r798[a1]);
              t2 = t0 + t1;
              r799[a0] = t2[13:0];
              a1 = a1 + 1;
            end
            a0 = a0 + 1;
          end
        end
        state <= 910;
      end
      910: begin  // instr 648 neg
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r788[a1]);
              t1 = 0 - t0;
              r800[a0] = t1[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 60;
        end
        state <= 911;
      end
      911: begin  // instr 649 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r795[a1]);
              r801[a0] = t0[9:0];
              a0 = a0 + 1;
            end
            a1 = a1 + 1;
          end
          a1 = a1 - 10;
        end
        state <= 912;
      end
      912: begin  // instr 650 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r800[a1]);
              t1 = $signed(r801[a2]);
              t2 = t0 - t1;
              r802[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a2 = a2 + 1;
          end
          a1 = a1 - 60;
          a2 = a2 - 10;
        end
        state <= 913;
      end
      913: begin  // instr 651 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r802[a1]);
              t1 = $signed(rom9_lit[a2]);
              t2 = (t0 < t1) ? t1 : t0;
              r803[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 60;
        end
        state <= 914;
      end
      914: begin  // instr 652 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          r804[a0] = t0[13:0];
          a0 = a0 + 1;
        end
        state <= 915;
      end
      915: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r804[a0]);
              t1 = $signed(r803[a1]);
              t2 = t0 + t1;
              r804[a0] = t2[13:0];
              a1 = a1 + 1;
            end
            a0 = a0 + 1;
          end
        end
        state <= 916;
      end
      916: begin  // instr 653 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = $signed(r799[a1]);
            t1 = $signed(r804[a2]);
            t2 = t0 + t1;
            r805[a0] = t2[14:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 - 10;
          a2 = a2 - 10;
        end
        state <= 917;
      end
      917: begin  // instr 654 gt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = $signed(r805[a1]);
            t1 = $signed(r789[a2]);
            t2 = (t0 > t1) ? 1 : 0;
            r806[a0] = (t2 != 0);
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 10;
        end
        state <= 918;
      end
      918: begin  // instr 655 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = r806[a1];
            t1 = $signed(r791[a2]);
            t2 = $signed(r795[a3]);
            t3 = (t0 != 0) ? t2 : t1;
            r807[a0] = t3[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
            a3 = a3 + 1;
          end
          a1 = a1 - 10;
          a2 = a2 - 10;
          a3 = a3 - 10;
        end
        state <= 919;
      end
      919: begin  // instr 656 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = r806[a1];
            t1 = $signed(r795[a2]);
            t2 = $signed(r792[a3]);
            t3 = (t0 != 0) ? t2 : t1;
            r808[a0] = t3[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
            a3 = a3 + 1;
          end
          a1 = a1 - 10;
          a2 = a2 - 10;
          a3 = a3 - 10;
        end
        state <= 920;
      end
      920: begin  // loop15.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r793[a1]);
          r790[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 921;
      end
      921: begin  // loop15.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          t0 = $signed(r807[a1]);
          r791[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 922;
      end
      922: begin  // loop15.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          t0 = $signed(r808[a1]);
          r792[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 923;
      end
      923: begin  // loop15.adv
        k15 = k15 + 1;
        state <= 901;
      end
      924: begin  // loop15.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r790[a1]);
          r809[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 925;
      end
      925: begin  // loop15.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          t0 = $signed(r791[a1]);
          r810[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 926;
      end
      926: begin  // loop15.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          t0 = $signed(r792[a1]);
          r811[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 927;
      end
      927: begin  // instr 657 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = $signed(r784[a1]);
            t1 = $signed(r811[a2]);
            t2 = t0 - t1;
            r812[a0] = t2[10:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 - 10;
          a2 = a2 - 10;
        end
        state <= 928;
      end
      928: begin  // instr 658 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = $signed(r812[a1]);
            t1 = t0 >>> 1;
            r813[a0] = t1[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 10;
        end
        state <= 929;
      end
      929: begin  // instr 659 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom15_lit[a1]);
        t1 = t0;
        r814[a0] = t1[7:0];
        state <= 930;
      end
      930: begin  // instr 660 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = $signed(r814[a1]);
            t1 = $signed(r813[a2]);
            t2 = (t0 < t1) ? t1 : t0;
            r815[a0] = t2[9:0];
            a0 = a0 + 1;
            a2 = a2 + 1;
          end
          a2 = a2 - 10;
        end
        state <= 931;
      end
      931: begin  // instr 661 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom16_lit[a1]);
        t1 = t0;
        r816[a0] = t1[7:0];
        state <= 932;
      end
      932: begin  // instr 662 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = $signed(r816[a1]);
            t1 = $signed(r815[a2]);
            t2 = (t1 < t0) ? t1 : t0;
            r817[a0] = t2[7:0];
            a0 = a0 + 1;
            a2 = a2 + 1;
          end
          a2 = a2 - 10;
        end
        state <= 933;
      end
      933: begin  // instr 663 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r624[a1]);
          t1 = $signed(r725[a2]);
          t2 = t0 - t1;
          r818[a0] = t2[5:0];
          a0 = a0 + 1;
        end
        state <= 934;
      end
      934: begin  // instr 664 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r818[a1]);
          t1 = $signed(rom8_lit[a2]);
          t2 = t0 + t1;
          r819[a0] = t2[5:0];
          a0 = a0 + 1;
        end
        state <= 935;
      end
      935: begin  // instr 665 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r819[a1]);
          t1 = $signed(rom9_lit[a2]);
          t2 = (t0 < t1) ? t1 : t0;
          r820[a0] = t2[5:0];
          a0 = a0 + 1;
        end
        state <= 936;
      end
      936: begin  // instr 666 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r820[a1]);
          t1 = t0 >>> 1;
          r821[a0] = t1[4:0];
          a0 = a0 + 1;
        end
        state <= 937;
      end
      937: begin  // instr 667 concat
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 15; c1 = c1 + 1) begin
            t0 = $signed(r4[a1]);
            r822[a0] = t0[7:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a0 = a0 + 10;
        end
        state <= 938;
      end
      938: begin  // concat
        a0 = 15;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = $signed(r817[a1]);
            r822[a0] = t0[7:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a0 = a0 + 15;
        end
        state <= 939;
      end
      939: begin  // instr 668 shl
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 25; c1 = c1 + 1) begin
            t0 = $signed(r822[a1]);
            t1 = t0 << 1;
            r823[a0] = t1[8:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 25;
        end
        state <= 940;
      end
      940: begin  // instr 669 mov
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(rom0_c[a1]);
            t1 = t0;
            r824[a0] = t1[5:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
        end
        state <= 941;
      end
      941: begin  // instr 670 rev
        a0 = 0;
        a1 = 15;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(r824[a1]);
            r825[a0] = t0[5:0];
            a0 = a0 + 1;
            a1 = a1 - 1;
          end
          a1 = a1 + 32;
        end
        state <= 942;
      end
      942: begin  // instr 671 reshape
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 80; c0 = c0 + 1) begin
          t0 = $signed(r825[a1]);
          r826[a0] = t0[5:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 943;
      end
      943: begin  // instr 672 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          t0 = a1;
          r827[a0] = t0[4:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 944;
      end
      944: begin  // instr 673 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            t0 = $signed(r827[a1]);
            r828[a0] = t0[4:0];
            a0 = a0 + 1;
          end
          a1 = a1 + 1;
        end
        state <= 945;
      end
      945: begin  // instr 674 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 16; c0 = c0 + 1) begin
          t0 = a1;
          r829[a0] = t0[4:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 946;
      end
      946: begin  // instr 675 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(r829[a1]);
            r830[a0] = t0[4:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 16;
        end
        state <= 947;
      end
      947: begin  // instr 676 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(r828[a1]);
            t1 = $signed(r830[a2]);
            t2 = t0 + t1;
            r831[a0] = t2[5:0];
            a0 = a0 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 + 1;
          a2 = a2 - 16;
        end
        state <= 948;
      end
      948: begin  // instr 677 lt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(r831[a1]);
            t1 = $signed(rom9_lit[a2]);
            t2 = (t0 < t1) ? 1 : 0;
            r832[a0] = (t2 != 0);
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
        end
        state <= 949;
      end
      949: begin  // instr 678 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(r831[a1]);
            t1 = $signed(rom25_lit[a2]);
            t2 = t0 + t1;
            r834[a0] = t2[6:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
        end
        state <= 950;
      end
      950: begin  // instr 679 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = r832[a1];
            t1 = $signed(r831[a2]);
            t2 = $signed(r834[a3]);
            t3 = (t0 != 0) ? t2 : t1;
            r835[a0] = t3[5:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
            a3 = a3 + 1;
          end
        end
        state <= 951;
      end
      951: begin  // instr 680 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r835[a1]);
              r836[a0] = t0[5:0];
              a0 = a0 + 1;
            end
            a1 = a1 + 1;
          end
        end
        state <= 952;
      end
      952: begin  // instr 681 gather
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 16; c2 = c2 + 1) begin
              t9 = 0;
              t0 = $signed(r836[a2]);
              t1 = (t0 < 0) ? 0 : t0;
              t1 = (t1 > 24) ? 24 : t1;
              t2 = t1;
              t9 = t9 + t2;
              t3 = $signed(r823[a1 + t9]);
              r837[a0] = t3[8:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a1 = a1 + 25;
          a2 = a2 - 160;
        end
        state <= 953;
      end
      953: begin  // instr 682 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r837[a1]);
                r838[a0] = t0[8:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 160;
          end
        end
        state <= 954;
      end
      954: begin  // instr 683 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r826[a1]);
                t1 = $signed(r838[a2]);
                t2 = t0 + t1;
                r839[a0] = t2[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
                a2 = a2 + 1;
              end
              a1 = a1 - 16;
            end
            a2 = a2 - 160;
          end
          a1 = a1 + 16;
        end
        state <= 955;
      end
      955: begin  // instr 684 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom11_lit[a1]);
        t1 = t0;
        r840[a0] = t1[9:0];
        state <= 956;
      end
      956: begin  // instr 685 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r840[a1]);
                t1 = $signed(r839[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r841[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 160;
          end
          a2 = a2 + 160;
        end
        state <= 957;
      end
      957: begin  // instr 686 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom12_lit[a1]);
        t1 = t0;
        r842[a0] = t1[9:0];
        state <= 958;
      end
      958: begin  // instr 687 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r842[a1]);
                t1 = $signed(r841[a2]);
                t2 = (t1 < t0) ? t1 : t0;
                r843[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 160;
          end
          a2 = a2 + 160;
        end
        state <= 959;
      end
      959: begin  // instr 688 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r826[a1]);
                t1 = $signed(r838[a2]);
                t2 = t0 - t1;
                r844[a0] = t2[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
                a2 = a2 + 1;
              end
              a1 = a1 - 16;
            end
            a2 = a2 - 160;
          end
          a1 = a1 + 16;
        end
        state <= 960;
      end
      960: begin  // instr 689 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom11_lit[a1]);
        t1 = t0;
        r845[a0] = t1[9:0];
        state <= 961;
      end
      961: begin  // instr 690 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r845[a1]);
                t1 = $signed(r844[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r846[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 160;
          end
          a2 = a2 + 160;
        end
        state <= 962;
      end
      962: begin  // instr 691 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom12_lit[a1]);
        t1 = t0;
        r847[a0] = t1[9:0];
        state <= 963;
      end
      963: begin  // instr 692 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r847[a1]);
                t1 = $signed(r846[a2]);
                t2 = (t1 < t0) ? t1 : t0;
                r848[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 160;
          end
          a2 = a2 + 160;
        end
        state <= 964;
      end
      964: begin  // instr 693 abs
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r843[a1]);
                t1 = (t0 < 0) ? (0 - t0) : t0;
                r849[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 160;
          end
          a1 = a1 + 160;
        end
        state <= 965;
      end
      965: begin  // instr 694 reduce_max
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 50; c0 = c0 + 1) begin
          r850[a0] = t0[9:0];
          a0 = a0 + 1;
        end
        state <= 966;
      end
      966: begin  // reduce.max.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r850[a0]);
                t1 = $signed(r849[a1]);
                t2 = (t0 < t1) ? t1 : t0;
                r850[a0] = t2[9:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 967;
      end
      967: begin  // instr 695 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r850[a1]);
              t1 = $signed(rom13_lit[a2]);
              t2 = t0 - t1;
              r851[a0] = t2[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 10;
          end
          a1 = a1 + 10;
        end
        state <= 968;
      end
      968: begin  // instr 696 loop
        k16 = 0;
        state <= 969;
      end
      969: begin  // loop16.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 800; c0 = c0 + 1) begin
          t0 = $signed(r843[a1]);
          r852[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 970;
      end
      970: begin  // loop16.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom13_lit[a1]);
          r853[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 971;
      end
      971: begin  // loop16.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom9_lit[a1]);
          r854[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 972;
      end
      972: begin  // loop16.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 50; c0 = c0 + 1) begin
          t0 = $signed(r851[a1]);
          r855[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 973;
      end
      973: begin  // loop16.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 50; c0 = c0 + 1) begin
          t0 = $signed(r850[a1]);
          r856[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 974;
      end
      974: begin  // loop16.head
        if (k16 == 12) state <= 997;
        else state <= 975;
      end
      975: begin  // instr 697 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r854[a1]);
        t1 = $signed(rom8_lit[a2]);
        t2 = t0 + t1;
        r857[a0] = t2[4:0];
        state <= 976;
      end
      976: begin  // instr 698 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r855[a1]);
              t1 = $signed(r856[a2]);
              t2 = t0 + t1;
              r858[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 10;
            a2 = a2 - 10;
          end
          a1 = a1 + 10;
          a2 = a2 + 10;
        end
        state <= 977;
      end
      977: begin  // instr 699 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r858[a1]);
              t1 = t0 >>> 1;
              r859[a0] = t1[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 10;
          end
          a1 = a1 + 10;
        end
        state <= 978;
      end
      978: begin  // instr 700 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r859[a1]);
                r860[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 10;
          end
          a1 = a1 + 10;
        end
        state <= 979;
      end
      979: begin  // instr 701 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r852[a1]);
                t1 = $signed(r860[a2]);
                t2 = t0 - t1;
                r861[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 160;
            a2 = a2 - 10;
          end
          a1 = a1 + 160;
          a2 = a2 + 10;
        end
        state <= 980;
      end
      980: begin  // instr 702 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r861[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r862[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 160;
          end
          a1 = a1 + 160;
        end
        state <= 981;
      end
      981: begin  // instr 703 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 50; c0 = c0 + 1) begin
          r863[a0] = t0[14:0];
          a0 = a0 + 1;
        end
        state <= 982;
      end
      982: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r863[a0]);
                t1 = $signed(r862[a1]);
                t2 = t0 + t1;
                r863[a0] = t2[14:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 983;
      end
      983: begin  // instr 704 neg
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r852[a1]);
                t1 = 0 - t0;
                r864[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 160;
          end
          a1 = a1 + 160;
        end
        state <= 984;
      end
      984: begin  // instr 705 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r859[a1]);
                r865[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 10;
          end
          a1 = a1 + 10;
        end
        state <= 985;
      end
      985: begin  // instr 706 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r864[a1]);
                t1 = $signed(r865[a2]);
                t2 = t0 - t1;
                r866[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 160;
            a2 = a2 - 10;
          end
          a1 = a1 + 160;
          a2 = a2 + 10;
        end
        state <= 986;
      end
      986: begin  // instr 707 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r866[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r867[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 160;
          end
          a1 = a1 + 160;
        end
        state <= 987;
      end
      987: begin  // instr 708 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 50; c0 = c0 + 1) begin
          r868[a0] = t0[14:0];
          a0 = a0 + 1;
        end
        state <= 988;
      end
      988: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r868[a0]);
                t1 = $signed(r867[a1]);
                t2 = t0 + t1;
                r868[a0] = t2[14:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 989;
      end
      989: begin  // instr 709 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r863[a1]);
              t1 = $signed(r868[a2]);
              t2 = t0 + t1;
              r869[a0] = t2[15:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 10;
            a2 = a2 - 10;
          end
          a1 = a1 + 10;
          a2 = a2 + 10;
        end
        state <= 990;
      end
      990: begin  // instr 710 gt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r869[a1]);
              t1 = $signed(r853[a2]);
              t2 = (t0 > t1) ? 1 : 0;
              r870[a0] = (t2 != 0);
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 10;
          end
          a1 = a1 + 10;
        end
        state <= 991;
      end
      991: begin  // instr 711 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = r870[a1];
              t1 = $signed(r855[a2]);
              t2 = $signed(r859[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r871[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 10;
            a2 = a2 - 10;
            a3 = a3 - 10;
          end
          a1 = a1 + 10;
          a2 = a2 + 10;
          a3 = a3 + 10;
        end
        state <= 992;
      end
      992: begin  // instr 712 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = r870[a1];
              t1 = $signed(r859[a2]);
              t2 = $signed(r856[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r872[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 10;
            a2 = a2 - 10;
            a3 = a3 - 10;
          end
          a1 = a1 + 10;
          a2 = a2 + 10;
          a3 = a3 + 10;
        end
        state <= 993;
      end
      993: begin  // loop16.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r857[a1]);
          r854[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 994;
      end
      994: begin  // loop16.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 50; c0 = c0 + 1) begin
          t0 = $signed(r871[a1]);
          r855[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 995;
      end
      995: begin  // loop16.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 50; c0 = c0 + 1) begin
          t0 = $signed(r872[a1]);
          r856[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 996;
      end
      996: begin  // loop16.adv
        k16 = k16 + 1;
        state <= 974;
      end
      997: begin  // loop16.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r854[a1]);
          r873[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 998;
      end
      998: begin  // loop16.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 50; c0 = c0 + 1) begin
          t0 = $signed(r855[a1]);
          r874[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 999;
      end
      999: begin  // loop16.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 50; c0 = c0 + 1) begin
          t0 = $signed(r856[a1]);
          r875[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1000;
      end
      1000: begin  // instr 713 abs
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r848[a1]);
                t1 = (t0 < 0) ? (0 - t0) : t0;
                r876[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 160;
          end
          a1 = a1 + 160;
        end
        state <= 1001;
      end
      1001: begin  // instr 714 reduce_max
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 50; c0 = c0 + 1) begin
          r877[a0] = t0[9:0];
          a0 = a0 + 1;
        end
        state <= 1002;
      end
      1002: begin  // reduce.max.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r877[a0]);
                t1 = $signed(r876[a1]);
                t2 = (t0 < t1) ? t1 : t0;
                r877[a0] = t2[9:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 1003;
      end
      1003: begin  // instr 715 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r877[a1]);
              t1 = $signed(rom13_lit[a2]);
              t2 = t0 - t1;
              r878[a0] = t2[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 10;
          end
          a1 = a1 + 10;
        end
        state <= 1004;
      end
      1004: begin  // instr 716 loop
        k17 = 0;
        state <= 1005;
      end
      1005: begin  // loop17.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 800; c0 = c0 + 1) begin
          t0 = $signed(r848[a1]);
          r879[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1006;
      end
      1006: begin  // loop17.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom13_lit[a1]);
          r880[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1007;
      end
      1007: begin  // loop17.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom9_lit[a1]);
          r881[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1008;
      end
      1008: begin  // loop17.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 50; c0 = c0 + 1) begin
          t0 = $signed(r878[a1]);
          r882[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1009;
      end
      1009: begin  // loop17.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 50; c0 = c0 + 1) begin
          t0 = $signed(r877[a1]);
          r883[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1010;
      end
      1010: begin  // loop17.head
        if (k17 == 12) state <= 1033;
        else state <= 1011;
      end
      1011: begin  // instr 717 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r881[a1]);
        t1 = $signed(rom8_lit[a2]);
        t2 = t0 + t1;
        r884[a0] = t2[4:0];
        state <= 1012;
      end
      1012: begin  // instr 718 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r882[a1]);
              t1 = $signed(r883[a2]);
              t2 = t0 + t1;
              r885[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 10;
            a2 = a2 - 10;
          end
          a1 = a1 + 10;
          a2 = a2 + 10;
        end
        state <= 1013;
      end
      1013: begin  // instr 719 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r885[a1]);
              t1 = t0 >>> 1;
              r886[a0] = t1[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 10;
          end
          a1 = a1 + 10;
        end
        state <= 1014;
      end
      1014: begin  // instr 720 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r886[a1]);
                r887[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 10;
          end
          a1 = a1 + 10;
        end
        state <= 1015;
      end
      1015: begin  // instr 721 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r879[a1]);
                t1 = $signed(r887[a2]);
                t2 = t0 - t1;
                r888[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 160;
            a2 = a2 - 10;
          end
          a1 = a1 + 160;
          a2 = a2 + 10;
        end
        state <= 1016;
      end
      1016: begin  // instr 722 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r888[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r889[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 160;
          end
          a1 = a1 + 160;
        end
        state <= 1017;
      end
      1017: begin  // instr 723 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 50; c0 = c0 + 1) begin
          r890[a0] = t0[14:0];
          a0 = a0 + 1;
        end
        state <= 1018;
      end
      1018: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r890[a0]);
                t1 = $signed(r889[a1]);
                t2 = t0 + t1;
                r890[a0] = t2[14:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 1019;
      end
      1019: begin  // instr 724 neg
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r879[a1]);
                t1 = 0 - t0;
                r891[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 160;
          end
          a1 = a1 + 160;
        end
        state <= 1020;
      end
      1020: begin  // instr 725 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r886[a1]);
                r892[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 10;
          end
          a1 = a1 + 10;
        end
        state <= 1021;
      end
      1021: begin  // instr 726 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r891[a1]);
                t1 = $signed(r892[a2]);
                t2 = t0 - t1;
                r893[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 160;
            a2 = a2 - 10;
          end
          a1 = a1 + 160;
          a2 = a2 + 10;
        end
        state <= 1022;
      end
      1022: begin  // instr 727 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r893[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r894[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 160;
          end
          a1 = a1 + 160;
        end
        state <= 1023;
      end
      1023: begin  // instr 728 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 50; c0 = c0 + 1) begin
          r895[a0] = t0[14:0];
          a0 = a0 + 1;
        end
        state <= 1024;
      end
      1024: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r895[a0]);
                t1 = $signed(r894[a1]);
                t2 = t0 + t1;
                r895[a0] = t2[14:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 1025;
      end
      1025: begin  // instr 729 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r890[a1]);
              t1 = $signed(r895[a2]);
              t2 = t0 + t1;
              r896[a0] = t2[15:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 10;
            a2 = a2 - 10;
          end
          a1 = a1 + 10;
          a2 = a2 + 10;
        end
        state <= 1026;
      end
      1026: begin  // instr 730 gt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r896[a1]);
              t1 = $signed(r880[a2]);
              t2 = (t0 > t1) ? 1 : 0;
              r897[a0] = (t2 != 0);
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 10;
          end
          a1 = a1 + 10;
        end
        state <= 1027;
      end
      1027: begin  // instr 731 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = r897[a1];
              t1 = $signed(r882[a2]);
              t2 = $signed(r886[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r898[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 10;
            a2 = a2 - 10;
            a3 = a3 - 10;
          end
          a1 = a1 + 10;
          a2 = a2 + 10;
          a3 = a3 + 10;
        end
        state <= 1028;
      end
      1028: begin  // instr 732 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = r897[a1];
              t1 = $signed(r886[a2]);
              t2 = $signed(r883[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r899[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 10;
            a2 = a2 - 10;
            a3 = a3 - 10;
          end
          a1 = a1 + 10;
          a2 = a2 + 10;
          a3 = a3 + 10;
        end
        state <= 1029;
      end
      1029: begin  // loop17.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r884[a1]);
          r881[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1030;
      end
      1030: begin  // loop17.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 50; c0 = c0 + 1) begin
          t0 = $signed(r898[a1]);
          r882[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1031;
      end
      1031: begin  // loop17.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 50; c0 = c0 + 1) begin
          t0 = $signed(r899[a1]);
          r883[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1032;
      end
      1032: begin  // loop17.adv
        k17 = k17 + 1;
        state <= 1010;
      end
      1033: begin  // loop17.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r881[a1]);
          r900[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1034;
      end
      1034: begin  // loop17.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 50; c0 = c0 + 1) begin
          t0 = $signed(r882[a1]);
          r901[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1035;
      end
      1035: begin  // loop17.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 50; c0 = c0 + 1) begin
          t0 = $signed(r883[a1]);
          r902[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1036;
      end
      1036: begin  // instr 733 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r875[a1]);
              t1 = $signed(r902[a2]);
              t2 = t0 - t1;
              r903[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 10;
            a2 = a2 - 10;
          end
          a1 = a1 + 10;
          a2 = a2 + 10;
        end
        state <= 1037;
      end
      1037: begin  // instr 734 transpose
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r903[a1]);
              r904[a0] = t0[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 40;
        end
        state <= 1038;
      end
      1038: begin  // instr 735 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            t0 = $signed(r821[a1]);
            r905[a0] = t0[4:0];
            a0 = a0 + 1;
          end
        end
        state <= 1039;
      end
      1039: begin  // instr 736 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r904[a1]);
              t1 = $signed(rom9_lit[a2]);
              t2 = (t0 < t1) ? t1 : t0;
              r906[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 50;
        end
        state <= 1040;
      end
      1040: begin  // instr 737 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = a1;
              r907[a0] = t0[4:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 10;
          end
        end
        state <= 1041;
      end
      1041: begin  // instr 738 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r905[a1]);
              r908[a0] = t0[4:0];
              a0 = a0 + 1;
            end
          end
        end
        state <= 1042;
      end
      1042: begin  // instr 739 lt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r907[a1]);
              t1 = $signed(r908[a2]);
              t2 = (t0 < t1) ? 1 : 0;
              r909[a0] = (t2 != 0);
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 50;
        end
        state <= 1043;
      end
      1043: begin  // instr 740 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom9_lit[a1]);
        t1 = t0;
        r910[a0] = t1[0:0];
        state <= 1044;
      end
      1044: begin  // instr 741 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r910[a1]);
              r911[a0] = t0[0:0];
              a0 = a0 + 1;
            end
          end
        end
        state <= 1045;
      end
      1045: begin  // instr 742 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = r909[a1];
              t1 = $signed(r911[a2]);
              t2 = $signed(r906[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r912[a0] = t3[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
          end
          a1 = a1 - 50;
          a2 = a2 - 50;
          a3 = a3 - 50;
        end
        state <= 1046;
      end
      1046: begin  // instr 743 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          r913[a0] = t0[13:0];
          a0 = a0 + 1;
        end
        state <= 1047;
      end
      1047: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r913[a0]);
              t1 = $signed(r912[a1]);
              t2 = t0 + t1;
              r913[a0] = t2[13:0];
              a1 = a1 + 1;
            end
            a0 = a0 + 1;
          end
        end
        state <= 1048;
      end
      1048: begin  // instr 744 shl
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            t0 = $signed(r913[a1]);
            t1 = t0 << 4;
            r915[a0] = t1[17:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 5;
        end
        state <= 1049;
      end
      1049: begin  // instr 745 lt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r821[a1]);
          t1 = $signed(rom9_lit[a2]);
          t2 = (t0 < t1) ? 1 : 0;
          r916[a0] = (t2 != 0);
          a0 = a0 + 1;
        end
        state <= 1050;
      end
      1050: begin  // instr 746 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r821[a1]);
          t1 = $signed(rom25_lit[a2]);
          t2 = t0 + t1;
          r917[a0] = t2[6:0];
          a0 = a0 + 1;
        end
        state <= 1051;
      end
      1051: begin  // instr 747 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = r916[a1];
          t1 = $signed(r821[a2]);
          t2 = $signed(r917[a3]);
          t3 = (t0 != 0) ? t2 : t1;
          r918[a0] = t3[4:0];
          a0 = a0 + 1;
        end
        state <= 1052;
      end
      1052: begin  // instr 748 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            t0 = $signed(r918[a1]);
            r919[a0] = t0[4:0];
            a0 = a0 + 1;
          end
        end
        state <= 1053;
      end
      1053: begin  // instr 749 gather
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 15; c1 = c1 + 1) begin
            t9 = 0;
            t0 = $signed(r919[a2]);
            t1 = (t0 < 0) ? 0 : t0;
            t1 = (t1 > 10) ? 10 : t1;
            t2 = t1;
            t9 = t9 + t2;
            t3 = $signed(r822[a1 + t9]);
            r920[a0] = t3[7:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 + 10;
          a2 = a2 + 1;
        end
        state <= 1054;
      end
      1054: begin  // instr 750 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r10[a1]);
          t1 = $signed(r821[a2]);
          t2 = t0 + t1;
          r921[a0] = t2;
          a0 = a0 + 1;
        end
        state <= 1055;
      end
      1055: begin  // instr 751 and
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r10[a1]);
          t1 = $signed(rom8_lit[a2]);
          t2 = t0 & t1;
          r922[a0] = t2[1:0];
          a0 = a0 + 1;
        end
        state <= 1056;
      end
      1056: begin  // instr 752 slice
        a0 = 0;
        a1 = 10;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 15; c1 = c1 + 1) begin
            t0 = $signed(r822[a1]);
            r923[a0] = t0[7:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 + 10;
        end
        state <= 1057;
      end
      1057: begin  // instr 753 shl
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 15; c1 = c1 + 1) begin
            t0 = $signed(r923[a1]);
            t1 = t0 << 1;
            r924[a0] = t1[8:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 15;
        end
        state <= 1058;
      end
      1058: begin  // instr 754 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom9_lit[a1]);
        t1 = t0;
        r925[a0] = t1[0:0];
        state <= 1059;
      end
      1059: begin  // instr 755 pad
        t0 = $signed(r925[0]);
        a0 = 0;
        for (c0 = 0; c0 < 16; c0 = c0 + 1) begin
          r926[a0] = t0[8:0];
          a0 = a0 + 1;
        end
        state <= 1060;
      end
      1060: begin  // pad.scatter
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 15; c1 = c1 + 1) begin
            t1 = $signed(r924[a1]);
            r926[a0] = t1[8:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a0 = a0 + 1;
        end
        state <= 1061;
      end
      1061: begin  // instr 756 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          t0 = a1;
          r927[a0] = t0[3:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1062;
      end
      1062: begin  // instr 757 shl
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          t0 = $signed(r927[a1]);
          t1 = t0 << 1;
          r928[a0] = t1[4:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1063;
      end
      1063: begin  // instr 758 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            t0 = $signed(r928[a1]);
            r929[a0] = t0[4:0];
            a0 = a0 + 1;
          end
          a1 = a1 + 1;
        end
        state <= 1064;
      end
      1064: begin  // instr 759 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 6; c0 = c0 + 1) begin
          t0 = a1;
          r930[a0] = t0[3:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1065;
      end
      1065: begin  // instr 760 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 6; c1 = c1 + 1) begin
            t0 = $signed(r930[a1]);
            r931[a0] = t0[3:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 6;
        end
        state <= 1066;
      end
      1066: begin  // instr 761 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 6; c1 = c1 + 1) begin
            t0 = $signed(r929[a1]);
            t1 = $signed(r931[a2]);
            t2 = t0 + t1;
            r932[a0] = t2[4:0];
            a0 = a0 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 + 1;
          a2 = a2 - 6;
        end
        state <= 1067;
      end
      1067: begin  // instr 762 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r932[a1]);
              r933[a0] = t0[4:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 30;
        end
        state <= 1068;
      end
      1068: begin  // instr 763 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r922[a1]);
              r934[a0] = t0[1:0];
              a0 = a0 + 1;
            end
          end
        end
        state <= 1069;
      end
      1069: begin  // instr 764 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r934[a1]);
              t1 = $signed(r933[a2]);
              t2 = t0 + t1;
              r935[a0] = t2[4:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a2 = a2 - 30;
        end
        state <= 1070;
      end
      1070: begin  // instr 765 lt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r935[a1]);
              t1 = $signed(rom9_lit[a2]);
              t2 = (t0 < t1) ? 1 : 0;
              r936[a0] = (t2 != 0);
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 30;
        end
        state <= 1071;
      end
      1071: begin  // instr 766 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r935[a1]);
              t1 = $signed(rom27_lit[a2]);
              t2 = t0 + t1;
              r938[a0] = t2[5:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 30;
        end
        state <= 1072;
      end
      1072: begin  // instr 767 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = r936[a1];
              t1 = $signed(r935[a2]);
              t2 = $signed(r938[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r939[a0] = t3[4:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
          end
          a1 = a1 - 30;
          a2 = a2 - 30;
          a3 = a3 - 30;
        end
        state <= 1073;
      end
      1073: begin  // instr 768 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r939[a1]);
                r940[a0] = t0[4:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 30;
        end
        state <= 1074;
      end
      1074: begin  // instr 769 gather
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t9 = 0;
              t0 = $signed(r940[a2]);
              t1 = (t0 < 0) ? 0 : t0;
              t1 = (t1 > 15) ? 15 : t1;
              t2 = t1;
              t9 = t9 + t2;
              t3 = $signed(r926[a1 + t9]);
              r941[a0] = t3[8:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a1 = a1 + 16;
        end
        state <= 1075;
      end
      1075: begin  // instr 770 mov
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 6; c0 = c0 + 1) begin
          t0 = $signed(rom1_c[a1]);
          t1 = t0;
          r942[a0] = t1[6:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1076;
      end
      1076: begin  // instr 771 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r942[a1]);
              r943[a0] = t0[6:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 6;
          end
        end
        state <= 1077;
      end
      1077: begin  // instr 772 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r943[a1]);
              t1 = $signed(r941[a2]);
              t2 = t0 + t1;
              r944[a0] = t2[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 6;
          end
          a2 = a2 - 30;
        end
        state <= 1078;
      end
      1078: begin  // instr 773 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom11_lit[a1]);
        t1 = t0;
        r945[a0] = t1[9:0];
        state <= 1079;
      end
      1079: begin  // instr 774 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r945[a1]);
              t1 = $signed(r944[a2]);
              t2 = (t0 < t1) ? t1 : t0;
              r946[a0] = t2[9:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a2 = a2 - 30;
        end
        state <= 1080;
      end
      1080: begin  // instr 775 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom12_lit[a1]);
        t1 = t0;
        r947[a0] = t1[9:0];
        state <= 1081;
      end
      1081: begin  // instr 776 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r947[a1]);
              t1 = $signed(r946[a2]);
              t2 = (t1 < t0) ? t1 : t0;
              r948[a0] = t2[9:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a2 = a2 - 30;
        end
        state <= 1082;
      end
      1082: begin  // instr 777 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r942[a1]);
              r949[a0] = t0[6:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 6;
          end
        end
        state <= 1083;
      end
      1083: begin  // instr 778 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r949[a1]);
              t1 = $signed(r941[a2]);
              t2 = t0 - t1;
              r950[a0] = t2[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 6;
          end
          a2 = a2 - 30;
        end
        state <= 1084;
      end
      1084: begin  // instr 779 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom11_lit[a1]);
        t1 = t0;
        r951[a0] = t1[9:0];
        state <= 1085;
      end
      1085: begin  // instr 780 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r951[a1]);
              t1 = $signed(r950[a2]);
              t2 = (t0 < t1) ? t1 : t0;
              r952[a0] = t2[9:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a2 = a2 - 30;
        end
        state <= 1086;
      end
      1086: begin  // instr 781 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom12_lit[a1]);
        t1 = t0;
        r953[a0] = t1[9:0];
        state <= 1087;
      end
      1087: begin  // instr 782 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r953[a1]);
              t1 = $signed(r952[a2]);
              t2 = (t1 < t0) ? t1 : t0;
              r954[a0] = t2[9:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a2 = a2 - 30;
        end
        state <= 1088;
      end
      1088: begin  // instr 783 abs
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r948[a1]);
              t1 = (t0 < 0) ? (0 - t0) : t0;
              r955[a0] = t1[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 30;
        end
        state <= 1089;
      end
      1089: begin  // instr 784 reduce_max
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          r956[a0] = t0[9:0];
          a0 = a0 + 1;
        end
        state <= 1090;
      end
      1090: begin  // reduce.max.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r956[a0]);
              t1 = $signed(r955[a1]);
              t2 = (t0 < t1) ? t1 : t0;
              r956[a0] = t2[9:0];
              a1 = a1 + 1;
            end
            a0 = a0 + 1;
          end
        end
        state <= 1091;
      end
      1091: begin  // instr 785 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            t0 = $signed(r956[a1]);
            t1 = $signed(rom13_lit[a2]);
            t2 = t0 - t1;
            r957[a0] = t2[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 5;
        end
        state <= 1092;
      end
      1092: begin  // instr 786 loop
        k18 = 0;
        state <= 1093;
      end
      1093: begin  // loop18.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 30; c0 = c0 + 1) begin
          t0 = $signed(r948[a1]);
          r958[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1094;
      end
      1094: begin  // loop18.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom13_lit[a1]);
          r959[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1095;
      end
      1095: begin  // loop18.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom9_lit[a1]);
          r960[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1096;
      end
      1096: begin  // loop18.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          t0 = $signed(r957[a1]);
          r961[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1097;
      end
      1097: begin  // loop18.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          t0 = $signed(r956[a1]);
          r962[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1098;
      end
      1098: begin  // loop18.head
        if (k18 == 12) state <= 1121;
        else state <= 1099;
      end
      1099: begin  // instr 787 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r960[a1]);
        t1 = $signed(rom8_lit[a2]);
        t2 = t0 + t1;
        r963[a0] = t2[4:0];
        state <= 1100;
      end
      1100: begin  // instr 788 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            t0 = $signed(r961[a1]);
            t1 = $signed(r962[a2]);
            t2 = t0 + t1;
            r964[a0] = t2[10:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 - 5;
          a2 = a2 - 5;
        end
        state <= 1101;
      end
      1101: begin  // instr 789 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            t0 = $signed(r964[a1]);
            t1 = t0 >>> 1;
            r965[a0] = t1[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 5;
        end
        state <= 1102;
      end
      1102: begin  // instr 790 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r965[a1]);
              r966[a0] = t0[9:0];
              a0 = a0 + 1;
            end
            a1 = a1 + 1;
          end
          a1 = a1 - 5;
        end
        state <= 1103;
      end
      1103: begin  // instr 791 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r958[a1]);
              t1 = $signed(r966[a2]);
              t2 = t0 - t1;
              r967[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a2 = a2 + 1;
          end
          a1 = a1 - 30;
          a2 = a2 - 5;
        end
        state <= 1104;
      end
      1104: begin  // instr 792 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r967[a1]);
              t1 = $signed(rom9_lit[a2]);
              t2 = (t0 < t1) ? t1 : t0;
              r968[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 30;
        end
        state <= 1105;
      end
      1105: begin  // instr 793 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          r969[a0] = t0[13:0];
          a0 = a0 + 1;
        end
        state <= 1106;
      end
      1106: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r969[a0]);
              t1 = $signed(r968[a1]);
              t2 = t0 + t1;
              r969[a0] = t2[13:0];
              a1 = a1 + 1;
            end
            a0 = a0 + 1;
          end
        end
        state <= 1107;
      end
      1107: begin  // instr 794 neg
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r958[a1]);
              t1 = 0 - t0;
              r970[a0] = t1[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 30;
        end
        state <= 1108;
      end
      1108: begin  // instr 795 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r965[a1]);
              r971[a0] = t0[9:0];
              a0 = a0 + 1;
            end
            a1 = a1 + 1;
          end
          a1 = a1 - 5;
        end
        state <= 1109;
      end
      1109: begin  // instr 796 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r970[a1]);
              t1 = $signed(r971[a2]);
              t2 = t0 - t1;
              r972[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a2 = a2 + 1;
          end
          a1 = a1 - 30;
          a2 = a2 - 5;
        end
        state <= 1110;
      end
      1110: begin  // instr 797 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r972[a1]);
              t1 = $signed(rom9_lit[a2]);
              t2 = (t0 < t1) ? t1 : t0;
              r973[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 30;
        end
        state <= 1111;
      end
      1111: begin  // instr 798 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          r974[a0] = t0[13:0];
          a0 = a0 + 1;
        end
        state <= 1112;
      end
      1112: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r974[a0]);
              t1 = $signed(r973[a1]);
              t2 = t0 + t1;
              r974[a0] = t2[13:0];
              a1 = a1 + 1;
            end
            a0 = a0 + 1;
          end
        end
        state <= 1113;
      end
      1113: begin  // instr 799 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            t0 = $signed(r969[a1]);
            t1 = $signed(r974[a2]);
            t2 = t0 + t1;
            r975[a0] = t2[14:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 - 5;
          a2 = a2 - 5;
        end
        state <= 1114;
      end
      1114: begin  // instr 800 gt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            t0 = $signed(r975[a1]);
            t1 = $signed(r959[a2]);
            t2 = (t0 > t1) ? 1 : 0;
            r976[a0] = (t2 != 0);
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 5;
        end
        state <= 1115;
      end
      1115: begin  // instr 801 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            t0 = r976[a1];
            t1 = $signed(r961[a2]);
            t2 = $signed(r965[a3]);
            t3 = (t0 != 0) ? t2 : t1;
            r977[a0] = t3[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
            a3 = a3 + 1;
          end
          a1 = a1 - 5;
          a2 = a2 - 5;
          a3 = a3 - 5;
        end
        state <= 1116;
      end
      1116: begin  // instr 802 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            t0 = r976[a1];
            t1 = $signed(r965[a2]);
            t2 = $signed(r962[a3]);
            t3 = (t0 != 0) ? t2 : t1;
            r978[a0] = t3[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
            a3 = a3 + 1;
          end
          a1 = a1 - 5;
          a2 = a2 - 5;
          a3 = a3 - 5;
        end
        state <= 1117;
      end
      1117: begin  // loop18.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r963[a1]);
          r960[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1118;
      end
      1118: begin  // loop18.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          t0 = $signed(r977[a1]);
          r961[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1119;
      end
      1119: begin  // loop18.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          t0 = $signed(r978[a1]);
          r962[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1120;
      end
      1120: begin  // loop18.adv
        k18 = k18 + 1;
        state <= 1098;
      end
      1121: begin  // loop18.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r960[a1]);
          r979[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1122;
      end
      1122: begin  // loop18.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          t0 = $signed(r961[a1]);
          r980[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1123;
      end
      1123: begin  // loop18.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          t0 = $signed(r962[a1]);
          r981[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1124;
      end
      1124: begin  // instr 803 abs
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r954[a1]);
              t1 = (t0 < 0) ? (0 - t0) : t0;
              r982[a0] = t1[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 30;
        end
        state <= 1125;
      end
      1125: begin  // instr 804 reduce_max
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          r983[a0] = t0[9:0];
          a0 = a0 + 1;
        end
        state <= 1126;
      end
      1126: begin  // reduce.max.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r983[a0]);
              t1 = $signed(r982[a1]);
              t2 = (t0 < t1) ? t1 : t0;
              r983[a0] = t2[9:0];
              a1 = a1 + 1;
            end
            a0 = a0 + 1;
          end
        end
        state <= 1127;
      end
      1127: begin  // instr 805 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            t0 = $signed(r983[a1]);
            t1 = $signed(rom13_lit[a2]);
            t2 = t0 - t1;
            r984[a0] = t2[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 5;
        end
        state <= 1128;
      end
      1128: begin  // instr 806 loop
        k19 = 0;
        state <= 1129;
      end
      1129: begin  // loop19.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 30; c0 = c0 + 1) begin
          t0 = $signed(r954[a1]);
          r985[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1130;
      end
      1130: begin  // loop19.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom13_lit[a1]);
          r986[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1131;
      end
      1131: begin  // loop19.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom9_lit[a1]);
          r987[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1132;
      end
      1132: begin  // loop19.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          t0 = $signed(r984[a1]);
          r988[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1133;
      end
      1133: begin  // loop19.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          t0 = $signed(r983[a1]);
          r989[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1134;
      end
      1134: begin  // loop19.head
        if (k19 == 12) state <= 1157;
        else state <= 1135;
      end
      1135: begin  // instr 807 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r987[a1]);
        t1 = $signed(rom8_lit[a2]);
        t2 = t0 + t1;
        r990[a0] = t2[4:0];
        state <= 1136;
      end
      1136: begin  // instr 808 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            t0 = $signed(r988[a1]);
            t1 = $signed(r989[a2]);
            t2 = t0 + t1;
            r991[a0] = t2[10:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 - 5;
          a2 = a2 - 5;
        end
        state <= 1137;
      end
      1137: begin  // instr 809 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            t0 = $signed(r991[a1]);
            t1 = t0 >>> 1;
            r992[a0] = t1[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 5;
        end
        state <= 1138;
      end
      1138: begin  // instr 810 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r992[a1]);
              r993[a0] = t0[9:0];
              a0 = a0 + 1;
            end
            a1 = a1 + 1;
          end
          a1 = a1 - 5;
        end
        state <= 1139;
      end
      1139: begin  // instr 811 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r985[a1]);
              t1 = $signed(r993[a2]);
              t2 = t0 - t1;
              r994[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a2 = a2 + 1;
          end
          a1 = a1 - 30;
          a2 = a2 - 5;
        end
        state <= 1140;
      end
      1140: begin  // instr 812 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r994[a1]);
              t1 = $signed(rom9_lit[a2]);
              t2 = (t0 < t1) ? t1 : t0;
              r995[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 30;
        end
        state <= 1141;
      end
      1141: begin  // instr 813 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          r996[a0] = t0[13:0];
          a0 = a0 + 1;
        end
        state <= 1142;
      end
      1142: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r996[a0]);
              t1 = $signed(r995[a1]);
              t2 = t0 + t1;
              r996[a0] = t2[13:0];
              a1 = a1 + 1;
            end
            a0 = a0 + 1;
          end
        end
        state <= 1143;
      end
      1143: begin  // instr 814 neg
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r985[a1]);
              t1 = 0 - t0;
              r997[a0] = t1[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 30;
        end
        state <= 1144;
      end
      1144: begin  // instr 815 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r992[a1]);
              r998[a0] = t0[9:0];
              a0 = a0 + 1;
            end
            a1 = a1 + 1;
          end
          a1 = a1 - 5;
        end
        state <= 1145;
      end
      1145: begin  // instr 816 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r997[a1]);
              t1 = $signed(r998[a2]);
              t2 = t0 - t1;
              r999[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a2 = a2 + 1;
          end
          a1 = a1 - 30;
          a2 = a2 - 5;
        end
        state <= 1146;
      end
      1146: begin  // instr 817 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r999[a1]);
              t1 = $signed(rom9_lit[a2]);
              t2 = (t0 < t1) ? t1 : t0;
              r1000[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 30;
        end
        state <= 1147;
      end
      1147: begin  // instr 818 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          r1001[a0] = t0[13:0];
          a0 = a0 + 1;
        end
        state <= 1148;
      end
      1148: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 6; c2 = c2 + 1) begin
              t0 = $signed(r1001[a0]);
              t1 = $signed(r1000[a1]);
              t2 = t0 + t1;
              r1001[a0] = t2[13:0];
              a1 = a1 + 1;
            end
            a0 = a0 + 1;
          end
        end
        state <= 1149;
      end
      1149: begin  // instr 819 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            t0 = $signed(r996[a1]);
            t1 = $signed(r1001[a2]);
            t2 = t0 + t1;
            r1002[a0] = t2[14:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 - 5;
          a2 = a2 - 5;
        end
        state <= 1150;
      end
      1150: begin  // instr 820 gt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            t0 = $signed(r1002[a1]);
            t1 = $signed(r986[a2]);
            t2 = (t0 > t1) ? 1 : 0;
            r1003[a0] = (t2 != 0);
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 5;
        end
        state <= 1151;
      end
      1151: begin  // instr 821 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            t0 = r1003[a1];
            t1 = $signed(r988[a2]);
            t2 = $signed(r992[a3]);
            t3 = (t0 != 0) ? t2 : t1;
            r1004[a0] = t3[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
            a3 = a3 + 1;
          end
          a1 = a1 - 5;
          a2 = a2 - 5;
          a3 = a3 - 5;
        end
        state <= 1152;
      end
      1152: begin  // instr 822 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            t0 = r1003[a1];
            t1 = $signed(r992[a2]);
            t2 = $signed(r989[a3]);
            t3 = (t0 != 0) ? t2 : t1;
            r1005[a0] = t3[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
            a3 = a3 + 1;
          end
          a1 = a1 - 5;
          a2 = a2 - 5;
          a3 = a3 - 5;
        end
        state <= 1153;
      end
      1153: begin  // loop19.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r990[a1]);
          r987[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1154;
      end
      1154: begin  // loop19.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          t0 = $signed(r1004[a1]);
          r988[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1155;
      end
      1155: begin  // loop19.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          t0 = $signed(r1005[a1]);
          r989[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1156;
      end
      1156: begin  // loop19.adv
        k19 = k19 + 1;
        state <= 1134;
      end
      1157: begin  // loop19.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r987[a1]);
          r1006[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1158;
      end
      1158: begin  // loop19.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          t0 = $signed(r988[a1]);
          r1007[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1159;
      end
      1159: begin  // loop19.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          t0 = $signed(r989[a1]);
          r1008[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1160;
      end
      1160: begin  // instr 823 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            t0 = $signed(r981[a1]);
            t1 = $signed(r1008[a2]);
            t2 = t0 - t1;
            r1009[a0] = t2[10:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 - 5;
          a2 = a2 - 5;
        end
        state <= 1161;
      end
      1161: begin  // instr 824 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            t0 = $signed(r1009[a1]);
            t1 = t0 >>> 1;
            r1010[a0] = t1[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 5;
        end
        state <= 1162;
      end
      1162: begin  // instr 825 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom15_lit[a1]);
        t1 = t0;
        r1011[a0] = t1[7:0];
        state <= 1163;
      end
      1163: begin  // instr 826 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            t0 = $signed(r1011[a1]);
            t1 = $signed(r1010[a2]);
            t2 = (t0 < t1) ? t1 : t0;
            r1012[a0] = t2[9:0];
            a0 = a0 + 1;
            a2 = a2 + 1;
          end
          a2 = a2 - 5;
        end
        state <= 1164;
      end
      1164: begin  // instr 827 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom16_lit[a1]);
        t1 = t0;
        r1013[a0] = t1[7:0];
        state <= 1165;
      end
      1165: begin  // instr 828 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            t0 = $signed(r1013[a1]);
            t1 = $signed(r1012[a2]);
            t2 = (t1 < t0) ? t1 : t0;
            r1014[a0] = t2[7:0];
            a0 = a0 + 1;
            a2 = a2 + 1;
          end
          a2 = a2 - 5;
        end
        state <= 1166;
      end
      1166: begin  // instr 829 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r821[a1]);
          t1 = $signed(r922[a2]);
          t2 = t0 - t1;
          r1015[a0] = t2[4:0];
          a0 = a0 + 1;
        end
        state <= 1167;
      end
      1167: begin  // instr 830 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r1015[a1]);
          t1 = $signed(rom8_lit[a2]);
          t2 = t0 + t1;
          r1016[a0] = t2[4:0];
          a0 = a0 + 1;
        end
        state <= 1168;
      end
      1168: begin  // instr 831 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r1016[a1]);
          t1 = $signed(rom9_lit[a2]);
          t2 = (t0 < t1) ? t1 : t0;
          r1017[a0] = t2[4:0];
          a0 = a0 + 1;
        end
        state <= 1169;
      end
      1169: begin  // instr 832 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r1017[a1]);
          t1 = t0 >>> 1;
          r1018[a0] = t1[3:0];
          a0 = a0 + 1;
        end
        state <= 1170;
      end
      1170: begin  // instr 833 concat
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 15; c1 = c1 + 1) begin
            t0 = $signed(r5[a1]);
            r1019[a0] = t0[7:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a0 = a0 + 5;
        end
        state <= 1171;
      end
      1171: begin  // concat
        a0 = 15;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            t0 = $signed(r1014[a1]);
            r1019[a0] = t0[7:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a0 = a0 + 15;
        end
        state <= 1172;
      end
      1172: begin  // instr 834 shl
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 20; c1 = c1 + 1) begin
            t0 = $signed(r1019[a1]);
            t1 = t0 << 1;
            r1020[a0] = t1[8:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 20;
        end
        state <= 1173;
      end
      1173: begin  // instr 835 mov
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(rom0_c[a1]);
            t1 = t0;
            r1021[a0] = t1[5:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
        end
        state <= 1174;
      end
      1174: begin  // instr 836 rev
        a0 = 0;
        a1 = 15;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(r1021[a1]);
            r1022[a0] = t0[5:0];
            a0 = a0 + 1;
            a1 = a1 - 1;
          end
          a1 = a1 + 32;
        end
        state <= 1175;
      end
      1175: begin  // instr 837 reshape
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 80; c0 = c0 + 1) begin
          t0 = $signed(r1022[a1]);
          r1023[a0] = t0[5:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1176;
      end
      1176: begin  // instr 838 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          t0 = a1;
          r1024[a0] = t0[3:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1177;
      end
      1177: begin  // instr 839 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            t0 = $signed(r1024[a1]);
            r1025[a0] = t0[3:0];
            a0 = a0 + 1;
          end
          a1 = a1 + 1;
        end
        state <= 1178;
      end
      1178: begin  // instr 840 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 16; c0 = c0 + 1) begin
          t0 = a1;
          r1026[a0] = t0[4:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1179;
      end
      1179: begin  // instr 841 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(r1026[a1]);
            r1027[a0] = t0[4:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 16;
        end
        state <= 1180;
      end
      1180: begin  // instr 842 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(r1025[a1]);
            t1 = $signed(r1027[a2]);
            t2 = t0 + t1;
            r1028[a0] = t2[5:0];
            a0 = a0 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 + 1;
          a2 = a2 - 16;
        end
        state <= 1181;
      end
      1181: begin  // instr 843 lt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(r1028[a1]);
            t1 = $signed(rom9_lit[a2]);
            t2 = (t0 < t1) ? 1 : 0;
            r1029[a0] = (t2 != 0);
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
        end
        state <= 1182;
      end
      1182: begin  // instr 844 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = $signed(r1028[a1]);
            t1 = $signed(rom28_lit[a2]);
            t2 = t0 + t1;
            r1031[a0] = t2[6:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
        end
        state <= 1183;
      end
      1183: begin  // instr 845 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            t0 = r1029[a1];
            t1 = $signed(r1028[a2]);
            t2 = $signed(r1031[a3]);
            t3 = (t0 != 0) ? t2 : t1;
            r1032[a0] = t3[5:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
            a3 = a3 + 1;
          end
        end
        state <= 1184;
      end
      1184: begin  // instr 846 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 16; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r1032[a1]);
              r1033[a0] = t0[5:0];
              a0 = a0 + 1;
            end
            a1 = a1 + 1;
          end
        end
        state <= 1185;
      end
      1185: begin  // instr 847 gather
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 16; c2 = c2 + 1) begin
              t9 = 0;
              t0 = $signed(r1033[a2]);
              t1 = (t0 < 0) ? 0 : t0;
              t1 = (t1 > 19) ? 19 : t1;
              t2 = t1;
              t9 = t9 + t2;
              t3 = $signed(r1020[a1 + t9]);
              r1034[a0] = t3[8:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a1 = a1 + 20;
          a2 = a2 - 80;
        end
        state <= 1186;
      end
      1186: begin  // instr 848 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 5; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r1034[a1]);
                r1035[a0] = t0[8:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 80;
          end
        end
        state <= 1187;
      end
      1187: begin  // instr 849 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 5; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r1023[a1]);
                t1 = $signed(r1035[a2]);
                t2 = t0 + t1;
                r1036[a0] = t2[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
                a2 = a2 + 1;
              end
              a1 = a1 - 16;
            end
            a2 = a2 - 80;
          end
          a1 = a1 + 16;
        end
        state <= 1188;
      end
      1188: begin  // instr 850 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom11_lit[a1]);
        t1 = t0;
        r1037[a0] = t1[9:0];
        state <= 1189;
      end
      1189: begin  // instr 851 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 5; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r1037[a1]);
                t1 = $signed(r1036[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r1038[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 80;
          end
          a2 = a2 + 80;
        end
        state <= 1190;
      end
      1190: begin  // instr 852 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom12_lit[a1]);
        t1 = t0;
        r1039[a0] = t1[9:0];
        state <= 1191;
      end
      1191: begin  // instr 853 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 5; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r1039[a1]);
                t1 = $signed(r1038[a2]);
                t2 = (t1 < t0) ? t1 : t0;
                r1040[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 80;
          end
          a2 = a2 + 80;
        end
        state <= 1192;
      end
      1192: begin  // instr 854 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 5; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r1023[a1]);
                t1 = $signed(r1035[a2]);
                t2 = t0 - t1;
                r1041[a0] = t2[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
                a2 = a2 + 1;
              end
              a1 = a1 - 16;
            end
            a2 = a2 - 80;
          end
          a1 = a1 + 16;
        end
        state <= 1193;
      end
      1193: begin  // instr 855 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom11_lit[a1]);
        t1 = t0;
        r1042[a0] = t1[9:0];
        state <= 1194;
      end
      1194: begin  // instr 856 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 5; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r1042[a1]);
                t1 = $signed(r1041[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r1043[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 80;
          end
          a2 = a2 + 80;
        end
        state <= 1195;
      end
      1195: begin  // instr 857 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom12_lit[a1]);
        t1 = t0;
        r1044[a0] = t1[9:0];
        state <= 1196;
      end
      1196: begin  // instr 858 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 5; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r1044[a1]);
                t1 = $signed(r1043[a2]);
                t2 = (t1 < t0) ? t1 : t0;
                r1045[a0] = t2[9:0];
                a0 = a0 + 1;
                a2 = a2 + 1;
              end
            end
            a2 = a2 - 80;
          end
          a2 = a2 + 80;
        end
        state <= 1197;
      end
      1197: begin  // instr 859 abs
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 5; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r1040[a1]);
                t1 = (t0 < 0) ? (0 - t0) : t0;
                r1046[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 80;
          end
          a1 = a1 + 80;
        end
        state <= 1198;
      end
      1198: begin  // instr 860 reduce_max
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 25; c0 = c0 + 1) begin
          r1047[a0] = t0[9:0];
          a0 = a0 + 1;
        end
        state <= 1199;
      end
      1199: begin  // reduce.max.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 5; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r1047[a0]);
                t1 = $signed(r1046[a1]);
                t2 = (t0 < t1) ? t1 : t0;
                r1047[a0] = t2[9:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 1200;
      end
      1200: begin  // instr 861 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 5; c2 = c2 + 1) begin
              t0 = $signed(r1047[a1]);
              t1 = $signed(rom13_lit[a2]);
              t2 = t0 - t1;
              r1048[a0] = t2[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 5;
          end
          a1 = a1 + 5;
        end
        state <= 1201;
      end
      1201: begin  // instr 862 loop
        k20 = 0;
        state <= 1202;
      end
      1202: begin  // loop20.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 400; c0 = c0 + 1) begin
          t0 = $signed(r1040[a1]);
          r1049[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1203;
      end
      1203: begin  // loop20.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom13_lit[a1]);
          r1050[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1204;
      end
      1204: begin  // loop20.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom9_lit[a1]);
          r1051[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1205;
      end
      1205: begin  // loop20.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 25; c0 = c0 + 1) begin
          t0 = $signed(r1048[a1]);
          r1052[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1206;
      end
      1206: begin  // loop20.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 25; c0 = c0 + 1) begin
          t0 = $signed(r1047[a1]);
          r1053[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1207;
      end
      1207: begin  // loop20.head
        if (k20 == 12) state <= 1230;
        else state <= 1208;
      end
      1208: begin  // instr 863 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r1051[a1]);
        t1 = $signed(rom8_lit[a2]);
        t2 = t0 + t1;
        r1054[a0] = t2[4:0];
        state <= 1209;
      end
      1209: begin  // instr 864 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 5; c2 = c2 + 1) begin
              t0 = $signed(r1052[a1]);
              t1 = $signed(r1053[a2]);
              t2 = t0 + t1;
              r1055[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 5;
            a2 = a2 - 5;
          end
          a1 = a1 + 5;
          a2 = a2 + 5;
        end
        state <= 1210;
      end
      1210: begin  // instr 865 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 5; c2 = c2 + 1) begin
              t0 = $signed(r1055[a1]);
              t1 = t0 >>> 1;
              r1056[a0] = t1[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 5;
          end
          a1 = a1 + 5;
        end
        state <= 1211;
      end
      1211: begin  // instr 866 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 5; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r1056[a1]);
                r1057[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 5;
          end
          a1 = a1 + 5;
        end
        state <= 1212;
      end
      1212: begin  // instr 867 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 5; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r1049[a1]);
                t1 = $signed(r1057[a2]);
                t2 = t0 - t1;
                r1058[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 80;
            a2 = a2 - 5;
          end
          a1 = a1 + 80;
          a2 = a2 + 5;
        end
        state <= 1213;
      end
      1213: begin  // instr 868 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 5; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r1058[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r1059[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 80;
          end
          a1 = a1 + 80;
        end
        state <= 1214;
      end
      1214: begin  // instr 869 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 25; c0 = c0 + 1) begin
          r1060[a0] = t0[14:0];
          a0 = a0 + 1;
        end
        state <= 1215;
      end
      1215: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 5; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r1060[a0]);
                t1 = $signed(r1059[a1]);
                t2 = t0 + t1;
                r1060[a0] = t2[14:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 1216;
      end
      1216: begin  // instr 870 neg
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 5; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r1049[a1]);
                t1 = 0 - t0;
                r1061[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 80;
          end
          a1 = a1 + 80;
        end
        state <= 1217;
      end
      1217: begin  // instr 871 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 5; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r1056[a1]);
                r1062[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 5;
          end
          a1 = a1 + 5;
        end
        state <= 1218;
      end
      1218: begin  // instr 872 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 5; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r1061[a1]);
                t1 = $signed(r1062[a2]);
                t2 = t0 - t1;
                r1063[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 80;
            a2 = a2 - 5;
          end
          a1 = a1 + 80;
          a2 = a2 + 5;
        end
        state <= 1219;
      end
      1219: begin  // instr 873 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 5; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r1063[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r1064[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 80;
          end
          a1 = a1 + 80;
        end
        state <= 1220;
      end
      1220: begin  // instr 874 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 25; c0 = c0 + 1) begin
          r1065[a0] = t0[14:0];
          a0 = a0 + 1;
        end
        state <= 1221;
      end
      1221: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 5; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r1065[a0]);
                t1 = $signed(r1064[a1]);
                t2 = t0 + t1;
                r1065[a0] = t2[14:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 1222;
      end
      1222: begin  // instr 875 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 5; c2 = c2 + 1) begin
              t0 = $signed(r1060[a1]);
              t1 = $signed(r1065[a2]);
              t2 = t0 + t1;
              r1066[a0] = t2[15:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 5;
            a2 = a2 - 5;
          end
          a1 = a1 + 5;
          a2 = a2 + 5;
        end
        state <= 1223;
      end
      1223: begin  // instr 876 gt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 5; c2 = c2 + 1) begin
              t0 = $signed(r1066[a1]);
              t1 = $signed(r1050[a2]);
              t2 = (t0 > t1) ? 1 : 0;
              r1067[a0] = (t2 != 0);
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 5;
          end
          a1 = a1 + 5;
        end
        state <= 1224;
      end
      1224: begin  // instr 877 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 5; c2 = c2 + 1) begin
              t0 = r1067[a1];
              t1 = $signed(r1052[a2]);
              t2 = $signed(r1056[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r1068[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 5;
            a2 = a2 - 5;
            a3 = a3 - 5;
          end
          a1 = a1 + 5;
          a2 = a2 + 5;
          a3 = a3 + 5;
        end
        state <= 1225;
      end
      1225: begin  // instr 878 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 5; c2 = c2 + 1) begin
              t0 = r1067[a1];
              t1 = $signed(r1056[a2]);
              t2 = $signed(r1053[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r1069[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 5;
            a2 = a2 - 5;
            a3 = a3 - 5;
          end
          a1 = a1 + 5;
          a2 = a2 + 5;
          a3 = a3 + 5;
        end
        state <= 1226;
      end
      1226: begin  // loop20.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r1054[a1]);
          r1051[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1227;
      end
      1227: begin  // loop20.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 25; c0 = c0 + 1) begin
          t0 = $signed(r1068[a1]);
          r1052[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1228;
      end
      1228: begin  // loop20.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 25; c0 = c0 + 1) begin
          t0 = $signed(r1069[a1]);
          r1053[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1229;
      end
      1229: begin  // loop20.adv
        k20 = k20 + 1;
        state <= 1207;
      end
      1230: begin  // loop20.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r1051[a1]);
          r1070[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1231;
      end
      1231: begin  // loop20.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 25; c0 = c0 + 1) begin
          t0 = $signed(r1052[a1]);
          r1071[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1232;
      end
      1232: begin  // loop20.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 25; c0 = c0 + 1) begin
          t0 = $signed(r1053[a1]);
          r1072[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1233;
      end
      1233: begin  // instr 879 abs
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 5; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r1045[a1]);
                t1 = (t0 < 0) ? (0 - t0) : t0;
                r1073[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 80;
          end
          a1 = a1 + 80;
        end
        state <= 1234;
      end
      1234: begin  // instr 880 reduce_max
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 25; c0 = c0 + 1) begin
          r1074[a0] = t0[9:0];
          a0 = a0 + 1;
        end
        state <= 1235;
      end
      1235: begin  // reduce.max.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 5; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r1074[a0]);
                t1 = $signed(r1073[a1]);
                t2 = (t0 < t1) ? t1 : t0;
                r1074[a0] = t2[9:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 1236;
      end
      1236: begin  // instr 881 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 5; c2 = c2 + 1) begin
              t0 = $signed(r1074[a1]);
              t1 = $signed(rom13_lit[a2]);
              t2 = t0 - t1;
              r1075[a0] = t2[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 5;
          end
          a1 = a1 + 5;
        end
        state <= 1237;
      end
      1237: begin  // instr 882 loop
        k21 = 0;
        state <= 1238;
      end
      1238: begin  // loop21.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 400; c0 = c0 + 1) begin
          t0 = $signed(r1045[a1]);
          r1076[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1239;
      end
      1239: begin  // loop21.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom13_lit[a1]);
          r1077[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1240;
      end
      1240: begin  // loop21.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom9_lit[a1]);
          r1078[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1241;
      end
      1241: begin  // loop21.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 25; c0 = c0 + 1) begin
          t0 = $signed(r1075[a1]);
          r1079[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1242;
      end
      1242: begin  // loop21.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 25; c0 = c0 + 1) begin
          t0 = $signed(r1074[a1]);
          r1080[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1243;
      end
      1243: begin  // loop21.head
        if (k21 == 12) state <= 1266;
        else state <= 1244;
      end
      1244: begin  // instr 883 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r1078[a1]);
        t1 = $signed(rom8_lit[a2]);
        t2 = t0 + t1;
        r1081[a0] = t2[4:0];
        state <= 1245;
      end
      1245: begin  // instr 884 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 5; c2 = c2 + 1) begin
              t0 = $signed(r1079[a1]);
              t1 = $signed(r1080[a2]);
              t2 = t0 + t1;
              r1082[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 5;
            a2 = a2 - 5;
          end
          a1 = a1 + 5;
          a2 = a2 + 5;
        end
        state <= 1246;
      end
      1246: begin  // instr 885 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 5; c2 = c2 + 1) begin
              t0 = $signed(r1082[a1]);
              t1 = t0 >>> 1;
              r1083[a0] = t1[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 5;
          end
          a1 = a1 + 5;
        end
        state <= 1247;
      end
      1247: begin  // instr 886 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 5; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r1083[a1]);
                r1084[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 5;
          end
          a1 = a1 + 5;
        end
        state <= 1248;
      end
      1248: begin  // instr 887 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 5; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r1076[a1]);
                t1 = $signed(r1084[a2]);
                t2 = t0 - t1;
                r1085[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 80;
            a2 = a2 - 5;
          end
          a1 = a1 + 80;
          a2 = a2 + 5;
        end
        state <= 1249;
      end
      1249: begin  // instr 888 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 5; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r1085[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r1086[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 80;
          end
          a1 = a1 + 80;
        end
        state <= 1250;
      end
      1250: begin  // instr 889 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 25; c0 = c0 + 1) begin
          r1087[a0] = t0[14:0];
          a0 = a0 + 1;
        end
        state <= 1251;
      end
      1251: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 5; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r1087[a0]);
                t1 = $signed(r1086[a1]);
                t2 = t0 + t1;
                r1087[a0] = t2[14:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 1252;
      end
      1252: begin  // instr 890 neg
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 5; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r1076[a1]);
                t1 = 0 - t0;
                r1088[a0] = t1[9:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 80;
          end
          a1 = a1 + 80;
        end
        state <= 1253;
      end
      1253: begin  // instr 891 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 5; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 1; c3 = c3 + 1) begin
                t0 = $signed(r1083[a1]);
                r1089[a0] = t0[9:0];
                a0 = a0 + 1;
              end
              a1 = a1 + 1;
            end
            a1 = a1 - 5;
          end
          a1 = a1 + 5;
        end
        state <= 1254;
      end
      1254: begin  // instr 892 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 5; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r1088[a1]);
                t1 = $signed(r1089[a2]);
                t2 = t0 - t1;
                r1090[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
              a2 = a2 + 1;
            end
            a1 = a1 - 80;
            a2 = a2 - 5;
          end
          a1 = a1 + 80;
          a2 = a2 + 5;
        end
        state <= 1255;
      end
      1255: begin  // instr 893 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 5; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r1090[a1]);
                t1 = $signed(rom9_lit[a2]);
                t2 = (t0 < t1) ? t1 : t0;
                r1091[a0] = t2[10:0];
                a0 = a0 + 1;
                a1 = a1 + 1;
              end
            end
            a1 = a1 - 80;
          end
          a1 = a1 + 80;
        end
        state <= 1256;
      end
      1256: begin  // instr 894 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 25; c0 = c0 + 1) begin
          r1092[a0] = t0[14:0];
          a0 = a0 + 1;
        end
        state <= 1257;
      end
      1257: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 5; c2 = c2 + 1) begin
              for (c3 = 0; c3 < 16; c3 = c3 + 1) begin
                t0 = $signed(r1092[a0]);
                t1 = $signed(r1091[a1]);
                t2 = t0 + t1;
                r1092[a0] = t2[14:0];
                a1 = a1 + 1;
              end
              a0 = a0 + 1;
            end
          end
        end
        state <= 1258;
      end
      1258: begin  // instr 895 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 5; c2 = c2 + 1) begin
              t0 = $signed(r1087[a1]);
              t1 = $signed(r1092[a2]);
              t2 = t0 + t1;
              r1093[a0] = t2[15:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 5;
            a2 = a2 - 5;
          end
          a1 = a1 + 5;
          a2 = a2 + 5;
        end
        state <= 1259;
      end
      1259: begin  // instr 896 gt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 5; c2 = c2 + 1) begin
              t0 = $signed(r1093[a1]);
              t1 = $signed(r1077[a2]);
              t2 = (t0 > t1) ? 1 : 0;
              r1094[a0] = (t2 != 0);
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 5;
          end
          a1 = a1 + 5;
        end
        state <= 1260;
      end
      1260: begin  // instr 897 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 5; c2 = c2 + 1) begin
              t0 = r1094[a1];
              t1 = $signed(r1079[a2]);
              t2 = $signed(r1083[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r1095[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 5;
            a2 = a2 - 5;
            a3 = a3 - 5;
          end
          a1 = a1 + 5;
          a2 = a2 + 5;
          a3 = a3 + 5;
        end
        state <= 1261;
      end
      1261: begin  // instr 898 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 5; c2 = c2 + 1) begin
              t0 = r1094[a1];
              t1 = $signed(r1083[a2]);
              t2 = $signed(r1080[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r1096[a0] = t3[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
            a1 = a1 - 5;
            a2 = a2 - 5;
            a3 = a3 - 5;
          end
          a1 = a1 + 5;
          a2 = a2 + 5;
          a3 = a3 + 5;
        end
        state <= 1262;
      end
      1262: begin  // loop21.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r1081[a1]);
          r1078[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1263;
      end
      1263: begin  // loop21.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 25; c0 = c0 + 1) begin
          t0 = $signed(r1095[a1]);
          r1079[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1264;
      end
      1264: begin  // loop21.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 25; c0 = c0 + 1) begin
          t0 = $signed(r1096[a1]);
          r1080[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1265;
      end
      1265: begin  // loop21.adv
        k21 = k21 + 1;
        state <= 1243;
      end
      1266: begin  // loop21.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r1078[a1]);
          r1097[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1267;
      end
      1267: begin  // loop21.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 25; c0 = c0 + 1) begin
          t0 = $signed(r1079[a1]);
          r1098[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1268;
      end
      1268: begin  // loop21.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 25; c0 = c0 + 1) begin
          t0 = $signed(r1080[a1]);
          r1099[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1269;
      end
      1269: begin  // instr 899 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 5; c2 = c2 + 1) begin
              t0 = $signed(r1072[a1]);
              t1 = $signed(r1099[a2]);
              t2 = t0 - t1;
              r1100[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
            end
            a1 = a1 - 5;
            a2 = a2 - 5;
          end
          a1 = a1 + 5;
          a2 = a2 + 5;
        end
        state <= 1270;
      end
      1270: begin  // instr 900 transpose
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 5; c2 = c2 + 1) begin
              t0 = $signed(r1100[a1]);
              r1101[a0] = t0[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 20;
        end
        state <= 1271;
      end
      1271: begin  // instr 901 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            t0 = $signed(r1018[a1]);
            r1102[a0] = t0[3:0];
            a0 = a0 + 1;
          end
        end
        state <= 1272;
      end
      1272: begin  // instr 902 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 5; c2 = c2 + 1) begin
              t0 = $signed(r1101[a1]);
              t1 = $signed(rom9_lit[a2]);
              t2 = (t0 < t1) ? t1 : t0;
              r1103[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 25;
        end
        state <= 1273;
      end
      1273: begin  // instr 903 iota
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 5; c2 = c2 + 1) begin
              t0 = a1;
              r1104[a0] = t0[3:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 5;
          end
        end
        state <= 1274;
      end
      1274: begin  // instr 904 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r1102[a1]);
              r1105[a0] = t0[3:0];
              a0 = a0 + 1;
            end
          end
        end
        state <= 1275;
      end
      1275: begin  // instr 905 lt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 5; c2 = c2 + 1) begin
              t0 = $signed(r1104[a1]);
              t1 = $signed(r1105[a2]);
              t2 = (t0 < t1) ? 1 : 0;
              r1106[a0] = (t2 != 0);
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 25;
        end
        state <= 1276;
      end
      1276: begin  // instr 906 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom9_lit[a1]);
        t1 = t0;
        r1107[a0] = t1[0:0];
        state <= 1277;
      end
      1277: begin  // instr 907 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 5; c2 = c2 + 1) begin
              t0 = $signed(r1107[a1]);
              r1108[a0] = t0[0:0];
              a0 = a0 + 1;
            end
          end
        end
        state <= 1278;
      end
      1278: begin  // instr 908 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 5; c2 = c2 + 1) begin
              t0 = r1106[a1];
              t1 = $signed(r1108[a2]);
              t2 = $signed(r1103[a3]);
              t3 = (t0 != 0) ? t2 : t1;
              r1109[a0] = t3[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
              a2 = a2 + 1;
              a3 = a3 + 1;
            end
          end
          a1 = a1 - 25;
          a2 = a2 - 25;
          a3 = a3 - 25;
        end
        state <= 1279;
      end
      1279: begin  // instr 909 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 5; c0 = c0 + 1) begin
          r1110[a0] = t0[12:0];
          a0 = a0 + 1;
        end
        state <= 1280;
      end
      1280: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 5; c2 = c2 + 1) begin
              t0 = $signed(r1110[a0]);
              t1 = $signed(r1109[a1]);
              t2 = t0 + t1;
              r1110[a0] = t2[12:0];
              a1 = a1 + 1;
            end
            a0 = a0 + 1;
          end
        end
        state <= 1281;
      end
      1281: begin  // instr 910 shl
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            t0 = $signed(r1110[a1]);
            t1 = t0 << 5;
            r1112[a0] = t1[17:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 5;
        end
        state <= 1282;
      end
      1282: begin  // instr 911 lt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r1018[a1]);
          t1 = $signed(rom9_lit[a2]);
          t2 = (t0 < t1) ? 1 : 0;
          r1113[a0] = (t2 != 0);
          a0 = a0 + 1;
        end
        state <= 1283;
      end
      1283: begin  // instr 912 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r1018[a1]);
          t1 = $signed(rom28_lit[a2]);
          t2 = t0 + t1;
          r1114[a0] = t2[5:0];
          a0 = a0 + 1;
        end
        state <= 1284;
      end
      1284: begin  // instr 913 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = r1113[a1];
          t1 = $signed(r1018[a2]);
          t2 = $signed(r1114[a3]);
          t3 = (t0 != 0) ? t2 : t1;
          r1115[a0] = t3[3:0];
          a0 = a0 + 1;
        end
        state <= 1285;
      end
      1285: begin  // instr 914 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            t0 = $signed(r1115[a1]);
            r1116[a0] = t0[3:0];
            a0 = a0 + 1;
          end
        end
        state <= 1286;
      end
      1286: begin  // instr 915 gather
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 15; c1 = c1 + 1) begin
            t9 = 0;
            t0 = $signed(r1116[a2]);
            t1 = (t0 < 0) ? 0 : t0;
            t1 = (t1 > 5) ? 5 : t1;
            t2 = t1;
            t9 = t9 + t2;
            t3 = $signed(r1019[a1 + t9]);
            r1117[a0] = t3[7:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 + 5;
          a2 = a2 + 1;
        end
        state <= 1287;
      end
      1287: begin  // instr 916 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r11[a1]);
          t1 = $signed(r1018[a2]);
          t2 = t0 + t1;
          r1118[a0] = t2;
          a0 = a0 + 1;
        end
        state <= 1288;
      end
      1288: begin  // instr 917 concat
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            t0 = $signed(r126[a1]);
            r1119[a0] = t0[17:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a0 = a0 + 25;
        end
        state <= 1289;
      end
      1289: begin  // concat
        a0 = 5;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            t0 = $signed(r324[a1]);
            r1119[a0] = t0[17:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a0 = a0 + 25;
        end
        state <= 1290;
      end
      1290: begin  // concat
        a0 = 10;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            t0 = $signed(r521[a1]);
            r1119[a0] = t0[17:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a0 = a0 + 25;
        end
        state <= 1291;
      end
      1291: begin  // concat
        a0 = 15;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            t0 = $signed(r718[a1]);
            r1119[a0] = t0[17:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a0 = a0 + 25;
        end
        state <= 1292;
      end
      1292: begin  // concat
        a0 = 20;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            t0 = $signed(r915[a1]);
            r1119[a0] = t0[17:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a0 = a0 + 25;
        end
        state <= 1293;
      end
      1293: begin  // concat
        a0 = 25;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 5; c1 = c1 + 1) begin
            t0 = $signed(r1112[a1]);
            r1119[a0] = t0[17:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a0 = a0 + 25;
        end
        state <= 1294;
      end
      1294: begin  // instr 918 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            t0 = $signed(r12[a1]);
            t1 = $signed(r1119[a2]);
            t2 = t0 + t1;
            r1120[a0] = t2[23:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 - 30;
          a2 = a2 - 30;
        end
        state <= 1295;
      end
      1295: begin  // instr 919 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r14[a1]);
          t1 = $signed(r17[a2]);
          t2 = t0 + t1;
          r1121[a0] = t2;
          a0 = a0 + 1;
        end
        state <= 1296;
      end
      1296: begin  // instr 920 mov
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 30; c0 = c0 + 1) begin
          t0 = $signed(rom2_c[a1]);
          t1 = t0;
          r1122[a0] = t1[0:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1297;
      end
      1297: begin  // instr 921 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            t0 = $signed(r1122[a1]);
            r1123[a0] = t0[0:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 30;
        end
        state <= 1298;
      end
      1298: begin  // instr 922 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            t0 = $signed(r1120[a1]);
            t1 = $signed(r1123[a2]);
            t2 = t0 - t1;
            r1124[a0] = t2[23:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 - 30;
          a2 = a2 - 30;
        end
        state <= 1299;
      end
      1299: begin  // instr 923 mov
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 30; c0 = c0 + 1) begin
          t0 = $signed(rom3_c[a1]);
          t1 = t0;
          r1125[a0] = t1[2:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1300;
      end
      1300: begin  // instr 924 ge
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 30; c0 = c0 + 1) begin
          t0 = $signed(r1125[a1]);
          t1 = $signed(rom9_lit[a2]);
          t2 = (t0 >= t1) ? 1 : 0;
          r1126[a0] = (t2 != 0);
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1301;
      end
      1301: begin  // instr 925 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 30; c0 = c0 + 1) begin
          t0 = $signed(r1125[a1]);
          t1 = $signed(rom9_lit[a2]);
          t2 = (t0 < t1) ? t1 : t0;
          r1127[a0] = t2[0:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1302;
      end
      1302: begin  // instr 926 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            t0 = $signed(r1127[a1]);
            r1128[a0] = t0[0:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 30;
        end
        state <= 1303;
      end
      1303: begin  // instr 927 shl
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            t0 = $signed(r1124[a1]);
            t1 = $signed(r1128[a2]);
            t2 = t0 << t1;
            r1129[a0] = t2[23:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 - 30;
          a2 = a2 - 30;
        end
        state <= 1304;
      end
      1304: begin  // instr 928 neg
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 30; c0 = c0 + 1) begin
          t0 = $signed(r1125[a1]);
          t1 = 0 - t0;
          r1130[a0] = t1[2:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1305;
      end
      1305: begin  // instr 929 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 30; c0 = c0 + 1) begin
          t0 = $signed(r1130[a1]);
          t1 = $signed(rom9_lit[a2]);
          t2 = (t0 < t1) ? t1 : t0;
          r1131[a0] = t2[2:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1306;
      end
      1306: begin  // instr 930 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            t0 = $signed(r1131[a1]);
            r1132[a0] = t0[2:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 30;
        end
        state <= 1307;
      end
      1307: begin  // instr 931 shra
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            t0 = $signed(r1124[a1]);
            t1 = $signed(r1132[a2]);
            t2 = t0 >>> t1;
            r1133[a0] = t2[20:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 - 30;
          a2 = a2 - 30;
        end
        state <= 1308;
      end
      1308: begin  // instr 932 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            t0 = r1126[a1];
            r1134[a0] = (t0 != 0);
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 30;
        end
        state <= 1309;
      end
      1309: begin  // instr 933 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            t0 = r1134[a1];
            t1 = $signed(r1133[a2]);
            t2 = $signed(r1129[a3]);
            t3 = (t0 != 0) ? t2 : t1;
            r1135[a0] = t3[20:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
            a3 = a3 + 1;
          end
          a1 = a1 - 30;
          a2 = a2 - 30;
          a3 = a3 - 30;
        end
        state <= 1310;
      end
      1310: begin  // instr 934 mov
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 30; c0 = c0 + 1) begin
          t0 = $signed(rom4_c[a1]);
          t1 = t0;
          r1136[a0] = t1[2:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1311;
      end
      1311: begin  // instr 935 ge
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 30; c0 = c0 + 1) begin
          t0 = $signed(r1136[a1]);
          t1 = $signed(rom9_lit[a2]);
          t2 = (t0 >= t1) ? 1 : 0;
          r1137[a0] = (t2 != 0);
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1312;
      end
      1312: begin  // instr 936 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 30; c0 = c0 + 1) begin
          t0 = $signed(r1136[a1]);
          t1 = $signed(rom9_lit[a2]);
          t2 = (t0 < t1) ? t1 : t0;
          r1138[a0] = t2[0:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1313;
      end
      1313: begin  // instr 937 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            t0 = $signed(r1138[a1]);
            r1139[a0] = t0[0:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 30;
        end
        state <= 1314;
      end
      1314: begin  // instr 938 shl
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            t0 = $signed(r1124[a1]);
            t1 = $signed(r1139[a2]);
            t2 = t0 << t1;
            r1140[a0] = t2[23:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 - 30;
          a2 = a2 - 30;
        end
        state <= 1315;
      end
      1315: begin  // instr 939 neg
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 30; c0 = c0 + 1) begin
          t0 = $signed(r1136[a1]);
          t1 = 0 - t0;
          r1141[a0] = t1[3:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1316;
      end
      1316: begin  // instr 940 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 30; c0 = c0 + 1) begin
          t0 = $signed(r1141[a1]);
          t1 = $signed(rom9_lit[a2]);
          t2 = (t0 < t1) ? t1 : t0;
          r1142[a0] = t2[3:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1317;
      end
      1317: begin  // instr 941 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            t0 = $signed(r1142[a1]);
            r1143[a0] = t0[3:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 30;
        end
        state <= 1318;
      end
      1318: begin  // instr 942 shra
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            t0 = $signed(r1124[a1]);
            t1 = $signed(r1143[a2]);
            t2 = t0 >>> t1;
            r1144[a0] = t2[19:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 - 30;
          a2 = a2 - 30;
        end
        state <= 1319;
      end
      1319: begin  // instr 943 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            t0 = r1137[a1];
            r1145[a0] = (t0 != 0);
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 30;
        end
        state <= 1320;
      end
      1320: begin  // instr 944 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            t0 = r1145[a1];
            t1 = $signed(r1144[a2]);
            t2 = $signed(r1140[a3]);
            t3 = (t0 != 0) ? t2 : t1;
            r1146[a0] = t3[20:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
            a3 = a3 + 1;
          end
          a1 = a1 - 30;
          a2 = a2 - 30;
          a3 = a3 - 30;
        end
        state <= 1321;
      end
      1321: begin  // instr 945 mov
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 30; c0 = c0 + 1) begin
          t0 = $signed(rom2_c[a1]);
          t1 = t0;
          r1147[a0] = t1[0:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1322;
      end
      1322: begin  // instr 946 gt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 30; c0 = c0 + 1) begin
          t0 = $signed(r1147[a1]);
          t1 = $signed(rom9_lit[a2]);
          t2 = (t0 > t1) ? 1 : 0;
          r1148[a0] = (t2 != 0);
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1323;
      end
      1323: begin  // instr 947 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            t0 = $signed(r1135[a1]);
            t1 = $signed(r1146[a2]);
            t2 = t0 + t1;
            r1149[a0] = t2[21:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 - 30;
          a2 = a2 - 30;
        end
        state <= 1324;
      end
      1324: begin  // instr 948 lt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 30; c0 = c0 + 1) begin
          t0 = $signed(r1147[a1]);
          t1 = $signed(rom9_lit[a2]);
          t2 = (t0 < t1) ? 1 : 0;
          r1150[a0] = (t2 != 0);
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1325;
      end
      1325: begin  // instr 949 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            t0 = $signed(r1135[a1]);
            t1 = $signed(r1146[a2]);
            t2 = t0 - t1;
            r1151[a0] = t2[20:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 - 30;
          a2 = a2 - 30;
        end
        state <= 1326;
      end
      1326: begin  // instr 950 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            t0 = r1150[a1];
            r1152[a0] = (t0 != 0);
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 30;
        end
        state <= 1327;
      end
      1327: begin  // instr 951 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            t0 = r1152[a1];
            t1 = $signed(r1135[a2]);
            t2 = $signed(r1151[a3]);
            t3 = (t0 != 0) ? t2 : t1;
            r1153[a0] = t3[20:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
            a3 = a3 + 1;
          end
          a1 = a1 - 30;
          a2 = a2 - 30;
          a3 = a3 - 30;
        end
        state <= 1328;
      end
      1328: begin  // instr 952 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            t0 = r1148[a1];
            r1154[a0] = (t0 != 0);
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 30;
        end
        state <= 1329;
      end
      1329: begin  // instr 953 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            t0 = r1154[a1];
            t1 = $signed(r1153[a2]);
            t2 = $signed(r1149[a3]);
            t3 = (t0 != 0) ? t2 : t1;
            r1155[a0] = t3[20:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
            a3 = a3 + 1;
          end
          a1 = a1 - 30;
          a2 = a2 - 30;
          a3 = a3 - 30;
        end
        state <= 1330;
      end
      1330: begin  // instr 954 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom15_lit[a1]);
        t1 = t0;
        r1156[a0] = t1[7:0];
        state <= 1331;
      end
      1331: begin  // instr 955 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            t0 = $signed(r1156[a1]);
            t1 = $signed(r1155[a2]);
            t2 = (t0 < t1) ? t1 : t0;
            r1157[a0] = t2[20:0];
            a0 = a0 + 1;
            a2 = a2 + 1;
          end
          a2 = a2 - 30;
        end
        state <= 1332;
      end
      1332: begin  // instr 956 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom16_lit[a1]);
        t1 = t0;
        r1158[a0] = t1[7:0];
        state <= 1333;
      end
      1333: begin  // instr 957 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            t0 = $signed(r1158[a1]);
            t1 = $signed(r1157[a2]);
            t2 = (t1 < t0) ? t1 : t0;
            r1159[a0] = t2[7:0];
            a0 = a0 + 1;
            a2 = a2 + 1;
          end
          a2 = a2 - 30;
        end
        state <= 1334;
      end
      1334: begin  // instr 958 shl
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            t0 = $signed(r1159[a1]);
            t1 = t0 << 1;
            r1160[a0] = t1[8:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 30;
        end
        state <= 1335;
      end
      1335: begin  // instr 959 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r1160[a1]);
              r1161[a0] = t0[8:0];
              a0 = a0 + 1;
            end
            a1 = a1 + 1;
          end
          a1 = a1 - 30;
        end
        state <= 1336;
      end
      1336: begin  // instr 960 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r1160[a1]);
              r1162[a0] = t0[8:0];
              a0 = a0 + 1;
            end
            a1 = a1 + 1;
          end
          a1 = a1 - 30;
        end
        state <= 1337;
      end
      1337: begin  // instr 961 neg
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r1162[a1]);
              t1 = 0 - t0;
              r1163[a0] = t1[8:0];
              a0 = a0 + 1;
            end
            a1 = a1 + 1;
          end
          a1 = a1 - 30;
        end
        state <= 1338;
      end
      1338: begin  // instr 962 mov
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 30; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = $signed(rom5_c[a1]);
            t1 = t0;
            r1164[a0] = t1[5:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
        end
        state <= 1339;
      end
      1339: begin  // instr 963 mov
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 30; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = $signed(rom6_c[a1]);
            t1 = t0;
            r1165[a0] = t1[5:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
        end
        state <= 1340;
      end
      1340: begin  // instr 964 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r1164[a1]);
              r1166[a0] = t0[5:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 300;
        end
        state <= 1341;
      end
      1341: begin  // instr 965 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r1166[a1]);
              t1 = $signed(r1161[a2]);
              t2 = t0 + t1;
              r1167[a0] = t2[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a2 = a2 + 1;
          end
          a1 = a1 - 300;
          a2 = a2 - 30;
        end
        state <= 1342;
      end
      1342: begin  // instr 966 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom11_lit[a1]);
        t1 = t0;
        r1168[a0] = t1[9:0];
        state <= 1343;
      end
      1343: begin  // instr 967 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r1168[a1]);
              t1 = $signed(r1167[a2]);
              t2 = (t0 < t1) ? t1 : t0;
              r1169[a0] = t2[9:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a2 = a2 - 300;
        end
        state <= 1344;
      end
      1344: begin  // instr 968 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom12_lit[a1]);
        t1 = t0;
        r1170[a0] = t1[9:0];
        state <= 1345;
      end
      1345: begin  // instr 969 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r1170[a1]);
              t1 = $signed(r1169[a2]);
              t2 = (t1 < t0) ? t1 : t0;
              r1171[a0] = t2[9:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a2 = a2 - 300;
        end
        state <= 1346;
      end
      1346: begin  // instr 970 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r1165[a1]);
              r1172[a0] = t0[5:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 300;
        end
        state <= 1347;
      end
      1347: begin  // instr 971 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r1172[a1]);
              t1 = $signed(r1163[a2]);
              t2 = t0 + t1;
              r1173[a0] = t2[8:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a2 = a2 + 1;
          end
          a1 = a1 - 300;
          a2 = a2 - 30;
        end
        state <= 1348;
      end
      1348: begin  // instr 972 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom11_lit[a1]);
        t1 = t0;
        r1174[a0] = t1[9:0];
        state <= 1349;
      end
      1349: begin  // instr 973 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r1174[a1]);
              t1 = $signed(r1173[a2]);
              t2 = (t0 < t1) ? t1 : t0;
              r1175[a0] = t2[9:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a2 = a2 - 300;
        end
        state <= 1350;
      end
      1350: begin  // instr 974 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom12_lit[a1]);
        t1 = t0;
        r1176[a0] = t1[9:0];
        state <= 1351;
      end
      1351: begin  // instr 975 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r1176[a1]);
              t1 = $signed(r1175[a2]);
              t2 = (t1 < t0) ? t1 : t0;
              r1177[a0] = t2[9:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a2 = a2 - 300;
        end
        state <= 1352;
      end
      1352: begin  // instr 976 concat
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r1171[a1]);
              r1178[a0] = t0[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a0 = a0 + 300;
        end
        state <= 1353;
      end
      1353: begin  // concat
        a0 = 300;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r1177[a1]);
              r1178[a0] = t0[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a0 = a0 + 300;
        end
        state <= 1354;
      end
      1354: begin  // instr 977 mov
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          t0 = $signed(rom7_c[a1]);
          t1 = t0;
          r1179[a0] = t1[0:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1355;
      end
      1355: begin  // instr 978 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r1179[a1]);
              r1180[a0] = t0[0:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 10;
          end
        end
        state <= 1356;
      end
      1356: begin  // instr 979 concat
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 60; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r1178[a1]);
              r1181[a0] = t0[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a0 = a0 + 10;
        end
        state <= 1357;
      end
      1357: begin  // concat
        a0 = 600;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r1180[a1]);
              r1181[a0] = t0[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a0 = a0 + 600;
        end
        state <= 1358;
      end
      1358: begin  // instr 980 transpose
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 61; c2 = c2 + 1) begin
              t0 = $signed(r1181[a1]);
              r1182[a0] = t0[9:0];
              a0 = a0 + 1;
              a1 = a1 + 10;
            end
            a1 = a1 - 609;
          end
          a1 = a1 + 600;
        end
        state <= 1359;
      end
      1359: begin  // instr 981 reduce_max
        t0 = -254;
        a0 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          r1183[a0] = t0[9:0];
          a0 = a0 + 1;
        end
        state <= 1360;
      end
      1360: begin  // reduce.max.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 61; c2 = c2 + 1) begin
              t0 = $signed(r1183[a0]);
              t1 = $signed(r1182[a1]);
              t2 = (t0 < t1) ? t1 : t0;
              r1183[a0] = t2[9:0];
              a1 = a1 + 1;
            end
            a0 = a0 + 1;
          end
        end
        state <= 1361;
      end
      1361: begin  // instr 982 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = $signed(r1183[a1]);
            t1 = $signed(rom30_lit[a2]);
            t2 = t0 - t1;
            r1185[a0] = t2[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 10;
        end
        state <= 1362;
      end
      1362: begin  // instr 983 loop
        k22 = 0;
        state <= 1363;
      end
      1363: begin  // loop22.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 610; c0 = c0 + 1) begin
          t0 = $signed(r1182[a1]);
          r1186[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1364;
      end
      1364: begin  // loop22.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom30_lit[a1]);
          r1187[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1365;
      end
      1365: begin  // loop22.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom9_lit[a1]);
          r1188[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1366;
      end
      1366: begin  // loop22.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          t0 = $signed(r1185[a1]);
          r1189[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1367;
      end
      1367: begin  // loop22.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          t0 = $signed(r1183[a1]);
          r1190[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1368;
      end
      1368: begin  // loop22.head
        if (k22 == 11) state <= 1384;
        else state <= 1369;
      end
      1369: begin  // instr 984 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r1188[a1]);
        t1 = $signed(rom8_lit[a2]);
        t2 = t0 + t1;
        r1191[a0] = t2[4:0];
        state <= 1370;
      end
      1370: begin  // instr 985 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = $signed(r1189[a1]);
            t1 = $signed(r1190[a2]);
            t2 = t0 + t1;
            r1192[a0] = t2[10:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 - 10;
          a2 = a2 - 10;
        end
        state <= 1371;
      end
      1371: begin  // instr 986 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = $signed(r1192[a1]);
            t1 = t0 >>> 1;
            r1193[a0] = t1[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 10;
        end
        state <= 1372;
      end
      1372: begin  // instr 987 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r1193[a1]);
              r1194[a0] = t0[9:0];
              a0 = a0 + 1;
            end
            a1 = a1 + 1;
          end
          a1 = a1 - 10;
        end
        state <= 1373;
      end
      1373: begin  // instr 988 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 61; c2 = c2 + 1) begin
              t0 = $signed(r1186[a1]);
              t1 = $signed(r1194[a2]);
              t2 = t0 - t1;
              r1195[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a2 = a2 + 1;
          end
          a1 = a1 - 610;
          a2 = a2 - 10;
        end
        state <= 1374;
      end
      1374: begin  // instr 989 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 61; c2 = c2 + 1) begin
              t0 = $signed(r1195[a1]);
              t1 = $signed(rom9_lit[a2]);
              t2 = (t0 < t1) ? t1 : t0;
              r1196[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 610;
        end
        state <= 1375;
      end
      1375: begin  // instr 990 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          r1197[a0] = t0[16:0];
          a0 = a0 + 1;
        end
        state <= 1376;
      end
      1376: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 61; c2 = c2 + 1) begin
              t0 = $signed(r1197[a0]);
              t1 = $signed(r1196[a1]);
              t2 = t0 + t1;
              r1197[a0] = t2[16:0];
              a1 = a1 + 1;
            end
            a0 = a0 + 1;
          end
        end
        state <= 1377;
      end
      1377: begin  // instr 991 gt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = $signed(r1197[a1]);
            t1 = $signed(r1187[a2]);
            t2 = (t0 > t1) ? 1 : 0;
            r1198[a0] = (t2 != 0);
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 10;
        end
        state <= 1378;
      end
      1378: begin  // instr 992 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = r1198[a1];
            t1 = $signed(r1189[a2]);
            t2 = $signed(r1193[a3]);
            t3 = (t0 != 0) ? t2 : t1;
            r1199[a0] = t3[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
            a3 = a3 + 1;
          end
          a1 = a1 - 10;
          a2 = a2 - 10;
          a3 = a3 - 10;
        end
        state <= 1379;
      end
      1379: begin  // instr 993 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = r1198[a1];
            t1 = $signed(r1193[a2]);
            t2 = $signed(r1190[a3]);
            t3 = (t0 != 0) ? t2 : t1;
            r1200[a0] = t3[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
            a3 = a3 + 1;
          end
          a1 = a1 - 10;
          a2 = a2 - 10;
          a3 = a3 - 10;
        end
        state <= 1380;
      end
      1380: begin  // loop22.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r1191[a1]);
          r1188[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1381;
      end
      1381: begin  // loop22.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          t0 = $signed(r1199[a1]);
          r1189[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1382;
      end
      1382: begin  // loop22.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          t0 = $signed(r1200[a1]);
          r1190[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1383;
      end
      1383: begin  // loop22.adv
        k22 = k22 + 1;
        state <= 1368;
      end
      1384: begin  // loop22.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r1188[a1]);
          r1201[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1385;
      end
      1385: begin  // loop22.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          t0 = $signed(r1189[a1]);
          r1202[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1386;
      end
      1386: begin  // loop22.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          t0 = $signed(r1190[a1]);
          r1203[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1387;
      end
      1387: begin  // instr 994 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r1165[a1]);
              r1204[a0] = t0[5:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 300;
        end
        state <= 1388;
      end
      1388: begin  // instr 995 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r1204[a1]);
              t1 = $signed(r1161[a2]);
              t2 = t0 + t1;
              r1205[a0] = t2[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a2 = a2 + 1;
          end
          a1 = a1 - 300;
          a2 = a2 - 30;
        end
        state <= 1389;
      end
      1389: begin  // instr 996 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom11_lit[a1]);
        t1 = t0;
        r1206[a0] = t1[9:0];
        state <= 1390;
      end
      1390: begin  // instr 997 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r1206[a1]);
              t1 = $signed(r1205[a2]);
              t2 = (t0 < t1) ? t1 : t0;
              r1207[a0] = t2[9:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a2 = a2 - 300;
        end
        state <= 1391;
      end
      1391: begin  // instr 998 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom12_lit[a1]);
        t1 = t0;
        r1208[a0] = t1[9:0];
        state <= 1392;
      end
      1392: begin  // instr 999 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r1208[a1]);
              t1 = $signed(r1207[a2]);
              t2 = (t1 < t0) ? t1 : t0;
              r1209[a0] = t2[9:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a2 = a2 - 300;
        end
        state <= 1393;
      end
      1393: begin  // instr 1000 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r1164[a1]);
              r1210[a0] = t0[5:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 300;
        end
        state <= 1394;
      end
      1394: begin  // instr 1001 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r1210[a1]);
              t1 = $signed(r1163[a2]);
              t2 = t0 + t1;
              r1211[a0] = t2[8:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a2 = a2 + 1;
          end
          a1 = a1 - 300;
          a2 = a2 - 30;
        end
        state <= 1395;
      end
      1395: begin  // instr 1002 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom11_lit[a1]);
        t1 = t0;
        r1212[a0] = t1[9:0];
        state <= 1396;
      end
      1396: begin  // instr 1003 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r1212[a1]);
              t1 = $signed(r1211[a2]);
              t2 = (t0 < t1) ? t1 : t0;
              r1213[a0] = t2[9:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a2 = a2 - 300;
        end
        state <= 1397;
      end
      1397: begin  // instr 1004 convert
        a0 = 0;
        a1 = 0;
        t0 = $signed(rom12_lit[a1]);
        t1 = t0;
        r1214[a0] = t1[9:0];
        state <= 1398;
      end
      1398: begin  // instr 1005 min
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r1214[a1]);
              t1 = $signed(r1213[a2]);
              t2 = (t1 < t0) ? t1 : t0;
              r1215[a0] = t2[9:0];
              a0 = a0 + 1;
              a2 = a2 + 1;
            end
          end
          a2 = a2 - 300;
        end
        state <= 1399;
      end
      1399: begin  // instr 1006 concat
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r1209[a1]);
              r1216[a0] = t0[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a0 = a0 + 300;
        end
        state <= 1400;
      end
      1400: begin  // concat
        a0 = 300;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 30; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r1215[a1]);
              r1216[a0] = t0[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a0 = a0 + 300;
        end
        state <= 1401;
      end
      1401: begin  // instr 1007 mov
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          t0 = $signed(rom7_c[a1]);
          t1 = t0;
          r1217[a0] = t1[0:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1402;
      end
      1402: begin  // instr 1008 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r1217[a1]);
              r1218[a0] = t0[0:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a1 = a1 - 10;
          end
        end
        state <= 1403;
      end
      1403: begin  // instr 1009 concat
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 60; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r1216[a1]);
              r1219[a0] = t0[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a0 = a0 + 10;
        end
        state <= 1404;
      end
      1404: begin  // concat
        a0 = 600;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 1; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 10; c2 = c2 + 1) begin
              t0 = $signed(r1218[a1]);
              r1219[a0] = t0[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a0 = a0 + 600;
        end
        state <= 1405;
      end
      1405: begin  // instr 1010 transpose
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 61; c2 = c2 + 1) begin
              t0 = $signed(r1219[a1]);
              r1220[a0] = t0[9:0];
              a0 = a0 + 1;
              a1 = a1 + 10;
            end
            a1 = a1 - 609;
          end
          a1 = a1 + 600;
        end
        state <= 1406;
      end
      1406: begin  // instr 1011 reduce_max
        t0 = -254;
        a0 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          r1221[a0] = t0[9:0];
          a0 = a0 + 1;
        end
        state <= 1407;
      end
      1407: begin  // reduce.max.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 61; c2 = c2 + 1) begin
              t0 = $signed(r1221[a0]);
              t1 = $signed(r1220[a1]);
              t2 = (t0 < t1) ? t1 : t0;
              r1221[a0] = t2[9:0];
              a1 = a1 + 1;
            end
            a0 = a0 + 1;
          end
        end
        state <= 1408;
      end
      1408: begin  // instr 1012 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = $signed(r1221[a1]);
            t1 = $signed(rom30_lit[a2]);
            t2 = t0 - t1;
            r1222[a0] = t2[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 10;
        end
        state <= 1409;
      end
      1409: begin  // instr 1013 loop
        k23 = 0;
        state <= 1410;
      end
      1410: begin  // loop23.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 610; c0 = c0 + 1) begin
          t0 = $signed(r1220[a1]);
          r1223[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1411;
      end
      1411: begin  // loop23.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom30_lit[a1]);
          r1224[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1412;
      end
      1412: begin  // loop23.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom9_lit[a1]);
          r1225[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1413;
      end
      1413: begin  // loop23.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          t0 = $signed(r1222[a1]);
          r1226[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1414;
      end
      1414: begin  // loop23.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          t0 = $signed(r1221[a1]);
          r1227[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1415;
      end
      1415: begin  // loop23.head
        if (k23 == 11) state <= 1431;
        else state <= 1416;
      end
      1416: begin  // instr 1014 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r1225[a1]);
        t1 = $signed(rom8_lit[a2]);
        t2 = t0 + t1;
        r1228[a0] = t2[4:0];
        state <= 1417;
      end
      1417: begin  // instr 1015 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = $signed(r1226[a1]);
            t1 = $signed(r1227[a2]);
            t2 = t0 + t1;
            r1229[a0] = t2[10:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 - 10;
          a2 = a2 - 10;
        end
        state <= 1418;
      end
      1418: begin  // instr 1016 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = $signed(r1229[a1]);
            t1 = t0 >>> 1;
            r1230[a0] = t1[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 10;
        end
        state <= 1419;
      end
      1419: begin  // instr 1017 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r1230[a1]);
              r1231[a0] = t0[9:0];
              a0 = a0 + 1;
            end
            a1 = a1 + 1;
          end
          a1 = a1 - 10;
        end
        state <= 1420;
      end
      1420: begin  // instr 1018 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 61; c2 = c2 + 1) begin
              t0 = $signed(r1223[a1]);
              t1 = $signed(r1231[a2]);
              t2 = t0 - t1;
              r1232[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a2 = a2 + 1;
          end
          a1 = a1 - 610;
          a2 = a2 - 10;
        end
        state <= 1421;
      end
      1421: begin  // instr 1019 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 61; c2 = c2 + 1) begin
              t0 = $signed(r1232[a1]);
              t1 = $signed(rom9_lit[a2]);
              t2 = (t0 < t1) ? t1 : t0;
              r1233[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 610;
        end
        state <= 1422;
      end
      1422: begin  // instr 1020 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          r1234[a0] = t0[16:0];
          a0 = a0 + 1;
        end
        state <= 1423;
      end
      1423: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 61; c2 = c2 + 1) begin
              t0 = $signed(r1234[a0]);
              t1 = $signed(r1233[a1]);
              t2 = t0 + t1;
              r1234[a0] = t2[16:0];
              a1 = a1 + 1;
            end
            a0 = a0 + 1;
          end
        end
        state <= 1424;
      end
      1424: begin  // instr 1021 gt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = $signed(r1234[a1]);
            t1 = $signed(r1224[a2]);
            t2 = (t0 > t1) ? 1 : 0;
            r1235[a0] = (t2 != 0);
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 10;
        end
        state <= 1425;
      end
      1425: begin  // instr 1022 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = r1235[a1];
            t1 = $signed(r1226[a2]);
            t2 = $signed(r1230[a3]);
            t3 = (t0 != 0) ? t2 : t1;
            r1236[a0] = t3[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
            a3 = a3 + 1;
          end
          a1 = a1 - 10;
          a2 = a2 - 10;
          a3 = a3 - 10;
        end
        state <= 1426;
      end
      1426: begin  // instr 1023 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = r1235[a1];
            t1 = $signed(r1230[a2]);
            t2 = $signed(r1227[a3]);
            t3 = (t0 != 0) ? t2 : t1;
            r1237[a0] = t3[9:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
            a3 = a3 + 1;
          end
          a1 = a1 - 10;
          a2 = a2 - 10;
          a3 = a3 - 10;
        end
        state <= 1427;
      end
      1427: begin  // loop23.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r1228[a1]);
          r1225[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1428;
      end
      1428: begin  // loop23.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          t0 = $signed(r1236[a1]);
          r1226[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1429;
      end
      1429: begin  // loop23.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          t0 = $signed(r1237[a1]);
          r1227[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1430;
      end
      1430: begin  // loop23.adv
        k23 = k23 + 1;
        state <= 1415;
      end
      1431: begin  // loop23.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r1225[a1]);
          r1238[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1432;
      end
      1432: begin  // loop23.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          t0 = $signed(r1226[a1]);
          r1239[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1433;
      end
      1433: begin  // loop23.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          t0 = $signed(r1227[a1]);
          r1240[a0] = t0[9:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1434;
      end
      1434: begin  // instr 1024 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r1203[a1]);
              r1241[a0] = t0[9:0];
              a0 = a0 + 1;
            end
            a1 = a1 + 1;
          end
          a1 = a1 - 10;
        end
        state <= 1435;
      end
      1435: begin  // instr 1025 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r1240[a1]);
              r1242[a0] = t0[9:0];
              a0 = a0 + 1;
            end
            a1 = a1 + 1;
          end
          a1 = a1 - 10;
        end
        state <= 1436;
      end
      1436: begin  // instr 1026 concat
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r1241[a1]);
              r1243[a0] = t0[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a0 = a0 + 1;
          end
        end
        state <= 1437;
      end
      1437: begin  // concat
        a0 = 1;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r1242[a1]);
              r1243[a0] = t0[9:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a0 = a0 + 1;
          end
        end
        state <= 1438;
      end
      1438: begin  // instr 1027 reduce_max
        t0 = -510;
        a0 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          r1244[a0] = t0[9:0];
          a0 = a0 + 1;
        end
        state <= 1439;
      end
      1439: begin  // reduce.max.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 2; c2 = c2 + 1) begin
              t0 = $signed(r1244[a0]);
              t1 = $signed(r1243[a1]);
              t2 = (t0 < t1) ? t1 : t0;
              r1244[a0] = t2[9:0];
              a1 = a1 + 1;
            end
            a0 = a0 + 1;
          end
        end
        state <= 1440;
      end
      1440: begin  // instr 1028 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = $signed(r1244[a1]);
            t1 = $signed(rom31_lit[a2]);
            t2 = t0 - t1;
            r1246[a0] = t2[10:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 10;
        end
        state <= 1441;
      end
      1441: begin  // instr 1029 loop
        k24 = 0;
        state <= 1442;
      end
      1442: begin  // loop24.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 20; c0 = c0 + 1) begin
          t0 = $signed(r1243[a1]);
          r1247[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1443;
      end
      1443: begin  // loop24.const
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom31_lit[a1]);
          r1248[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1444;
      end
      1444: begin  // loop24.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(rom9_lit[a1]);
          r1249[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1445;
      end
      1445: begin  // loop24.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          t0 = $signed(r1246[a1]);
          r1250[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1446;
      end
      1446: begin  // loop24.carry0
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          t0 = $signed(r1244[a1]);
          r1251[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1447;
      end
      1447: begin  // loop24.head
        if (k24 == 8) state <= 1463;
        else state <= 1448;
      end
      1448: begin  // instr 1030 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        t0 = $signed(r1249[a1]);
        t1 = $signed(rom8_lit[a2]);
        t2 = t0 + t1;
        r1252[a0] = t2[4:0];
        state <= 1449;
      end
      1449: begin  // instr 1031 add
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = $signed(r1250[a1]);
            t1 = $signed(r1251[a2]);
            t2 = t0 + t1;
            r1253[a0] = t2[11:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 - 10;
          a2 = a2 - 10;
        end
        state <= 1450;
      end
      1450: begin  // instr 1032 shra
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = $signed(r1253[a1]);
            t1 = t0 >>> 1;
            r1254[a0] = t1[10:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 10;
        end
        state <= 1451;
      end
      1451: begin  // instr 1033 broadcast
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 1; c2 = c2 + 1) begin
              t0 = $signed(r1254[a1]);
              r1255[a0] = t0[10:0];
              a0 = a0 + 1;
            end
            a1 = a1 + 1;
          end
          a1 = a1 - 10;
        end
        state <= 1452;
      end
      1452: begin  // instr 1034 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 2; c2 = c2 + 1) begin
              t0 = $signed(r1247[a1]);
              t1 = $signed(r1255[a2]);
              t2 = t0 - t1;
              r1256[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
            a2 = a2 + 1;
          end
          a1 = a1 - 20;
          a2 = a2 - 10;
        end
        state <= 1453;
      end
      1453: begin  // instr 1035 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 2; c2 = c2 + 1) begin
              t0 = $signed(r1256[a1]);
              t1 = $signed(rom9_lit[a2]);
              t2 = (t0 < t1) ? t1 : t0;
              r1257[a0] = t2[10:0];
              a0 = a0 + 1;
              a1 = a1 + 1;
            end
          end
          a1 = a1 - 20;
        end
        state <= 1454;
      end
      1454: begin  // instr 1036 reduce_sum
        t0 = 0;
        a0 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          r1258[a0] = t0[11:0];
          a0 = a0 + 1;
        end
        state <= 1455;
      end
      1455: begin  // reduce.sum.acc
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            for (c2 = 0; c2 < 2; c2 = c2 + 1) begin
              t0 = $signed(r1258[a0]);
              t1 = $signed(r1257[a1]);
              t2 = t0 + t1;
              r1258[a0] = t2[11:0];
              a1 = a1 + 1;
            end
            a0 = a0 + 1;
          end
        end
        state <= 1456;
      end
      1456: begin  // instr 1037 gt
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = $signed(r1258[a1]);
            t1 = $signed(r1248[a2]);
            t2 = (t0 > t1) ? 1 : 0;
            r1259[a0] = (t2 != 0);
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 10;
        end
        state <= 1457;
      end
      1457: begin  // instr 1038 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = r1259[a1];
            t1 = $signed(r1250[a2]);
            t2 = $signed(r1254[a3]);
            t3 = (t0 != 0) ? t2 : t1;
            r1260[a0] = t3[10:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
            a3 = a3 + 1;
          end
          a1 = a1 - 10;
          a2 = a2 - 10;
          a3 = a3 - 10;
        end
        state <= 1458;
      end
      1458: begin  // instr 1039 select_n
        a0 = 0;
        a1 = 0;
        a2 = 0;
        a3 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = r1259[a1];
            t1 = $signed(r1254[a2]);
            t2 = $signed(r1251[a3]);
            t3 = (t0 != 0) ? t2 : t1;
            r1261[a0] = t3[10:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
            a3 = a3 + 1;
          end
          a1 = a1 - 10;
          a2 = a2 - 10;
          a3 = a3 - 10;
        end
        state <= 1459;
      end
      1459: begin  // loop24.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r1252[a1]);
          r1249[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1460;
      end
      1460: begin  // loop24.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          t0 = $signed(r1260[a1]);
          r1250[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1461;
      end
      1461: begin  // loop24.knext
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          t0 = $signed(r1261[a1]);
          r1251[a0] = t0;
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1462;
      end
      1462: begin  // loop24.adv
        k24 = k24 + 1;
        state <= 1447;
      end
      1463: begin  // loop24.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          t0 = $signed(r1249[a1]);
          r1262[a0] = t0[10:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1464;
      end
      1464: begin  // loop24.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          t0 = $signed(r1250[a1]);
          r1263[a0] = t0[10:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1465;
      end
      1465: begin  // loop24.out
        a0 = 0;
        a1 = 0;
        for (c0 = 0; c0 < 10; c0 = c0 + 1) begin
          t0 = $signed(r1251[a1]);
          r1264[a0] = t0[10:0];
          a0 = a0 + 1;
          a1 = a1 + 1;
        end
        state <= 1466;
      end
      1466: begin  // instr 1040 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = $signed(r1203[a1]);
            t1 = $signed(r1264[a2]);
            t2 = t0 - t1;
            r1265[a0] = t2[10:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 - 10;
          a2 = a2 - 10;
        end
        state <= 1467;
      end
      1467: begin  // instr 1041 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = $signed(r1265[a1]);
            t1 = $signed(rom9_lit[a2]);
            t2 = (t0 < t1) ? t1 : t0;
            r1266[a0] = t2[10:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 10;
        end
        state <= 1468;
      end
      1468: begin  // instr 1042 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = $signed(r1240[a1]);
            t1 = $signed(r1264[a2]);
            t2 = t0 - t1;
            r1267[a0] = t2[10:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 - 10;
          a2 = a2 - 10;
        end
        state <= 1469;
      end
      1469: begin  // instr 1043 max
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = $signed(r1267[a1]);
            t1 = $signed(rom9_lit[a2]);
            t2 = (t0 < t1) ? t1 : t0;
            r1268[a0] = t2[10:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
          end
          a1 = a1 - 10;
        end
        state <= 1470;
      end
      1470: begin  // instr 1044 sub
        a0 = 0;
        a1 = 0;
        a2 = 0;
        for (c0 = 0; c0 < 1; c0 = c0 + 1) begin
          for (c1 = 0; c1 < 10; c1 = c1 + 1) begin
            t0 = $signed(r1266[a1]);
            t1 = $signed(r1268[a2]);
            t2 = t0 - t1;
            r1269[a0] = t2[10:0];
            a0 = a0 + 1;
            a1 = a1 + 1;
            a2 = a2 + 1;
          end
          a1 = a1 - 10;
          a2 = a2 - 10;
        end
        state <= 1471;
      end
      1471: begin done <= 1; end
      default: state <= 0;
      endcase
    end
  end
endmodule

module session_step_q_top(input wire clk, input wire rst, input wire start, output wire done);
  session_step_q u_core(.clk(clk), .rst(rst), .start(start), .done(done));
endmodule
