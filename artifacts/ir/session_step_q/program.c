/* Generated fixed-point reference — see repro.ir.cgen. Do not edit. */
#include <stdint.h>
#include <stdio.h>
#include <stdlib.h>
#include <string.h>

static int32_t add32(int32_t a, int32_t b) {
    return (int32_t)((uint32_t)a + (uint32_t)b);
}
static int32_t sub32(int32_t a, int32_t b) {
    return (int32_t)((uint32_t)a - (uint32_t)b);
}
static int32_t neg32(int32_t a) { return (int32_t)(0u - (uint32_t)a); }
static int32_t min32(int32_t a, int32_t b) { return a < b ? a : b; }
static int32_t max32(int32_t a, int32_t b) { return a > b ? a : b; }
static int32_t abs32(int32_t a) { return a < 0 ? neg32(a) : a; }
static int32_t sign32(int32_t a) { return a > 0 ? 1 : (a < 0 ? -1 : 0); }
static int32_t shl32(int32_t x, int32_t k) {
    if (k >= 32 || k < 0) return 0;
    return (int32_t)((uint32_t)x << k);
}
static int32_t asr32(int32_t x, int32_t k) {
    if (k < 0) k = 0;
    if (k >= 32) return x < 0 ? -1 : 0;
    if (k == 0) return x;
    {
        uint32_t s = (uint32_t)x >> k;
        if (x < 0) s |= ~(uint32_t)0 << (32 - k);
        return (int32_t)s;
    }
}
static int32_t shrl32(int32_t x, int32_t k) {
    if (k >= 32 || k < 0) return 0;
    return (int32_t)((uint32_t)x >> k);
}
static long clamp_start(long s, long dim, long size) {
    if (s < 0) s = 0;
    if (s > dim - size) s = dim - size;
    return s;
}

static const int32_t rom0_c[80] = {
    2, 0, -7, 1, 17, -10, -25, 20, 20, -25, -10, 17,
    1, -7, 0, 2, -2, 2, 1, -12, 11, 9, -29, 16,
    16, -29, 9, 11, -12, 1, 2, -2, 0, -3, 6, -5,
    -7, 22, -28, 12, 12, -28, 22, -7, -5, 6, -3, 0,
    0, 0, -4, 10, -19, 22, -20, 7, 7, -20, 22, -19,
    10, -4, 0, 0, -6, 7, -14, 21, -26, 25, -19, 7,
    7, -19, 25, -26, 21, -14, 7, -6
};
static const int32_t rom1_c[6] = {
    -1, 8, 56, 56, 8, -1
};
static const int32_t rom2_c[30] = {
    0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0,
    0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0,
    0, 0, 0, 0, 0, 0
};
static const int32_t rom3_c[30] = {
    -3, -3, -3, -3, -3, -3, -3, -3, -3, -3, -3, -3,
    -3, -3, -3, -3, -3, -3, -3, -3, -3, -3, -3, -3,
    -3, -3, -3, -3, -3, -3
};
static const int32_t rom4_c[30] = {
    -4, -4, -4, -4, -4, -4, -4, -4, -4, -4, -4, -4,
    -4, -4, -4, -4, -4, -4, -4, -4, -4, -4, -4, -4,
    -4, -4, -4, -4, -4, -4
};
static const int32_t rom5_c[300] = {
    11, 10, 13, 13, 2, 9, 3, 14, 6, 13, 6, 6,
    10, 6, 5, 1, 5, 12, 5, 3, 3, 15, 12, 10,
    14, 0, 12, 12, 2, 2, 8, 0, 2, 14, 14, 0,
    1, 2, 1, 12, 10, 10, 6, 5, 14, 8, 6, 15,
    5, 15, 12, 5, 4, 13, 9, 1, 6, 1, 5, 10,
    10, 9, 1, 16, 5, 9, 0, 7, 9, 7, 13, 12,
    4, 6, 9, 15, 11, 8, 1, 7, 10, 2, 14, 6,
    8, 10, 11, 9, 14, 9, 13, 13, 1, 9, 12, 5,
    0, 0, 11, 8, 7, 11, 15, 14, 1, 6, 14, 12,
    10, 11, 0, 15, 5, 2, 15, 15, 7, 11, 4, 8,
    12, 13, 12, 1, 6, 5, 6, 6, 14, 3, 14, 3,
    2, 9, 3, 12, 15, 13, 4, 6, 5, 8, 6, 0,
    2, 7, 3, 2, 14, 13, 2, 15, 8, 5, 8, 8,
    13, 12, 7, 1, 10, 2, 10, 15, 4, 15, 1, 0,
    5, 1, 11, 15, 5, 11, 15, 9, 11, 2, 1, 5,
    14, 15, 6, 10, 8, 15, 1, 2, 2, 0, 5, 5,
    8, 4, 12, 7, 6, 3, 12, 0, 0, 6, 7, 3,
    2, 6, 0, 10, 3, 5, 0, 4, 13, 15, 14, 16,
    3, 10, 9, 14, 4, 12, 1, 9, 1, 13, 2, 0,
    1, 5, 5, 0, 15, 14, 15, 16, 3, 5, 8, 12,
    15, 3, 12, 1, 15, 13, 6, 15, 3, 0, 14, 3,
    4, 4, 2, 9, 6, 6, 7, 9, 1, 15, 12, 8,
    5, 3, 2, 8, 4, 4, 9, 0, 14, 15, 12, 6,
    14, 5, 14, 2, 6, 14, 15, 0, 11, 0, 7, 15,
    5, 10, 0, 6, 2, 5, 6, 7, 2, 11, 6, 9
};
static const int32_t rom6_c[300] = {
    3, 2, 15, 6, 8, 8, 0, 15, 13, 7, 15, 13,
    12, 12, 10, 7, 7, 8, 5, 6, 11, 11, 10, 1,
    3, 8, 12, 5, 1, 6, 12, 4, 10, 7, 1, 9,
    15, 13, 11, 2, 11, 13, 1, 0, 1, 6, 5, 16,
    4, 12, 8, 3, 4, 7, 14, 7, 7, 5, 15, 12,
    15, 2, 9, 8, 14, 6, 1, 3, 3, 0, 9, 4,
    7, 12, 10, 16, 11, 1, 4, 11, 13, 1, 14, 2,
    8, 10, 8, 2, 2, 12, 2, 7, 4, 9, 9, 6,
    4, 5, 2, 9, 11, 8, 12, 1, 7, 4, 0, 9,
    13, 12, 5, 4, 12, 3, 8, 14, 7, 2, 8, 9,
    12, 10, 8, 0, 15, 11, 15, 12, 8, 15, 9, 5,
    7, 13, 1, 11, 12, 11, 11, 3, 2, 12, 0, 5,
    15, 2, 9, 14, 4, 2, 13, 8, 1, 7, 2, 13,
    4, 6, 13, 7, 0, 10, 3, 7, 14, 7, 1, 15,
    9, 11, 11, 8, 9, 13, 11, 12, 0, 6, 6, 6,
    12, 10, 10, 12, 2, 6, 2, 6, 3, 15, 2, 3,
    15, 13, 0, 3, 12, 5, 7, 3, 7, 16, 4, 10,
    6, 5, 5, 1, 5, 13, 12, 0, 12, 6, 1, 11,
    0, 14, 5, 7, 2, 14, 7, 9, 13, 12, 2, 9,
    0, 3, 9, 5, 14, 15, 14, 7, 0, 1, 8, 9,
    14, 16, 8, 7, 12, 4, 7, 10, 5, 15, 8, 1,
    3, 12, 2, 11, 2, 4, 16, 11, 5, 12, 8, 4,
    12, 7, 7, 11, 3, 0, 9, 7, 7, 8, 6, 3,
    7, 4, 12, 7, 4, 14, 14, 14, 5, 14, 7, 13,
    11, 14, 9, 8, 12, 2, 1, 12, 3, 9, 0, 0
};
static const int32_t rom7_c[10] = {
    0, 0, 0, 0, 0, 0, 0, 0, 0, 0
};
static const int32_t rom8_lit[1] = {
    1
};
static const int32_t rom9_lit[1] = {
    0
};
static const int32_t rom10_lit[1] = {
    175
};
static const int32_t rom11_lit[1] = {
    -512
};
static const int32_t rom12_lit[1] = {
    511
};
static const int32_t rom13_lit[1] = {
    512
};
static const int32_t rom14_lit[1] = {
    166
};
static const int32_t rom15_lit[1] = {
    -128
};
static const int32_t rom16_lit[1] = {
    127
};
static const int32_t rom17_lit[1] = {
    95
};
static const int32_t rom18_lit[1] = {
    86
};
static const int32_t rom19_lit[1] = {
    55
};
static const int32_t rom20_lit[1] = {
    2
};
static const int32_t rom21_lit[1] = {
    46
};
static const int32_t rom22_lit[1] = {
    35
};
static const int32_t rom23_lit[1] = {
    3
};
static const int32_t rom24_lit[1] = {
    26
};
static const int32_t rom25_lit[1] = {
    25
};
static const int32_t rom26_lit[1] = {
    4
};
static const int32_t rom27_lit[1] = {
    16
};
static const int32_t rom28_lit[1] = {
    20
};
static const int32_t rom29_lit[1] = {
    5
};
static const int32_t rom30_lit[1] = {
    256
};
static const int32_t rom31_lit[1] = {
    32
};

static int32_t r0[15];
static int32_t r1[15];
static int32_t r2[15];
static int32_t r3[15];
static int32_t r4[15];
static int32_t r5[15];
static int32_t r6[1];
static int32_t r7[1];
static int32_t r8[1];
static int32_t r9[1];
static int32_t r10[1];
static int32_t r11[1];
static int32_t r12[30];
static int32_t r13[1];
static int32_t r14[1];
static uint8_t r15[1];
static int32_t r16[160];
static int32_t r17[1];
static const int32_t *const r18 = rom0_c;
static const int32_t *const r19 = rom1_c;
static const int32_t *const r20 = rom2_c;
static const int32_t *const r21 = rom3_c;
static const int32_t *const r22 = rom4_c;
static const int32_t *const r23 = rom5_c;
static const int32_t *const r24 = rom6_c;
static const int32_t *const r25 = rom7_c;
static int32_t r26[160];
static int32_t r27[1];
static int32_t r28[1];
static int32_t r29[175];
static const int32_t *const r30 = rom8_lit;
static int32_t r31[175];
static int32_t r32[80];
static int32_t r33[80];
static int32_t r34[80];
static int32_t r35[160];
static int32_t r36[160];
static int32_t r37[16];
static int32_t r38[16];
static int32_t r39[2560];
static const int32_t *const r40 = rom9_lit;
static uint8_t r41[2560];
static const int32_t *const r42 = rom10_lit;
static int32_t r43[2560];
static int32_t r44[2560];
static int32_t r45[2560];
static int32_t r46[2560];
static int32_t r47[2560];
static int32_t r48[12800];
static const int32_t *const r49 = rom11_lit;
static const int32_t *const r50 = rom12_lit;
static int32_t r51[1];
static int32_t r52[12800];
static int32_t r53[1];
static int32_t r54[12800];
static int32_t r55[12800];
static int32_t r56[1];
static int32_t r57[12800];
static int32_t r58[1];
static int32_t r59[12800];
static int32_t r60[12800];
static int32_t r61[800];
static const int32_t *const r62 = rom13_lit;
static int32_t r63[800];
static int32_t r64[12800];
static int32_t r65[1];
static int32_t r66[1];
static int32_t r67[800];
static int32_t r68[800];
static int32_t r69[1];
static int32_t r70[800];
static int32_t r71[800];
static int32_t r72[800];
static int32_t r73[12800];
static int32_t r74[12800];
static int32_t r75[800];
static int32_t r76[12800];
static int32_t r77[800];
static int32_t r78[12800];
static int32_t r79[12800];
static int32_t r80[800];
static int32_t r81[800];
static uint8_t r82[800];
static int32_t r83[800];
static int32_t r84[800];
static int32_t r85[1];
static int32_t r86[800];
static int32_t r87[800];
static int32_t r88[12800];
static int32_t r89[800];
static int32_t r90[800];
static int32_t r91[12800];
static int32_t r92[1];
static int32_t r93[1];
static int32_t r94[800];
static int32_t r95[800];
static int32_t r96[1];
static int32_t r97[800];
static int32_t r98[800];
static int32_t r99[800];
static int32_t r100[12800];
static int32_t r101[12800];
static int32_t r102[800];
static int32_t r103[12800];
static int32_t r104[800];
static int32_t r105[12800];
static int32_t r106[12800];
static int32_t r107[800];
static int32_t r108[800];
static uint8_t r109[800];
static int32_t r110[800];
static int32_t r111[800];
static int32_t r112[1];
static int32_t r113[800];
static int32_t r114[800];
static int32_t r115[800];
static int32_t r116[800];
static int32_t r117[1];
static int32_t r118[800];
static int32_t r119[800];
static int32_t r120[1];
static uint8_t r121[800];
static int32_t r122[1];
static int32_t r123[800];
static int32_t r124[800];
static int32_t r125[5];
static int32_t r126[5];
static uint8_t r127[1];
static int32_t r128[1];
static int32_t r129[1];
static int32_t r130[1];
static int32_t r131[15];
static int32_t r132[1];
static int32_t r133[1];
static int32_t r134[165];
static int32_t r135[165];
static int32_t r136[1];
static int32_t r137[166];
static int32_t r138[80];
static int32_t r139[80];
static int32_t r140[80];
static int32_t r141[6];
static int32_t r142[6];
static int32_t r143[480];
static int32_t r144[480];
static int32_t r145[1];
static int32_t r146[480];
static uint8_t r147[480];
static const int32_t *const r148 = rom14_lit;
static int32_t r149[480];
static int32_t r150[480];
static int32_t r151[480];
static int32_t r152[480];
static int32_t r153[6];
static int32_t r154[6];
static int32_t r155[480];
static int32_t r156[1];
static int32_t r157[480];
static int32_t r158[1];
static int32_t r159[480];
static int32_t r160[6];
static int32_t r161[480];
static int32_t r162[1];
static int32_t r163[480];
static int32_t r164[1];
static int32_t r165[480];
static int32_t r166[480];
static int32_t r167[80];
static int32_t r168[80];
static int32_t r169[480];
static int32_t r170[1];
static int32_t r171[1];
static int32_t r172[80];
static int32_t r173[80];
static int32_t r174[1];
static int32_t r175[80];
static int32_t r176[80];
static int32_t r177[80];
static int32_t r178[480];
static int32_t r179[480];
static int32_t r180[80];
static int32_t r181[480];
static int32_t r182[80];
static int32_t r183[480];
static int32_t r184[480];
static int32_t r185[80];
static int32_t r186[80];
static uint8_t r187[80];
static int32_t r188[80];
static int32_t r189[80];
static int32_t r190[1];
static int32_t r191[80];
static int32_t r192[80];
static int32_t r193[480];
static int32_t r194[80];
static int32_t r195[80];
static int32_t r196[480];
static int32_t r197[1];
static int32_t r198[1];
static int32_t r199[80];
static int32_t r200[80];
static int32_t r201[1];
static int32_t r202[80];
static int32_t r203[80];
static int32_t r204[80];
static int32_t r205[480];
static int32_t r206[480];
static int32_t r207[80];
static int32_t r208[480];
static int32_t r209[80];
static int32_t r210[480];
static int32_t r211[480];
static int32_t r212[80];
static int32_t r213[80];
static uint8_t r214[80];
static int32_t r215[80];
static int32_t r216[80];
static int32_t r217[1];
static int32_t r218[80];
static int32_t r219[80];
static int32_t r220[80];
static int32_t r221[80];
static const int32_t *const r222 = rom15_lit;
static const int32_t *const r223 = rom16_lit;
static int32_t r224[1];
static int32_t r225[80];
static int32_t r226[1];
static int32_t r227[80];
static int32_t r228[1];
static int32_t r229[1];
static int32_t r230[1];
static int32_t r231[1];
static int32_t r232[95];
static int32_t r233[95];
static int32_t r234[80];
static int32_t r235[80];
static int32_t r236[80];
static int32_t r237[80];
static int32_t r238[80];
static int32_t r239[16];
static int32_t r240[16];
static int32_t r241[1280];
static uint8_t r242[1280];
static const int32_t *const r243 = rom17_lit;
static int32_t r244[1280];
static int32_t r245[1280];
static int32_t r246[1280];
static int32_t r247[1280];
static int32_t r248[1280];
static int32_t r249[6400];
static int32_t r250[1];
static int32_t r251[6400];
static int32_t r252[1];
static int32_t r253[6400];
static int32_t r254[6400];
static int32_t r255[1];
static int32_t r256[6400];
static int32_t r257[1];
static int32_t r258[6400];
static int32_t r259[6400];
static int32_t r260[400];
static int32_t r261[400];
static int32_t r262[6400];
static int32_t r263[1];
static int32_t r264[1];
static int32_t r265[400];
static int32_t r266[400];
static int32_t r267[1];
static int32_t r268[400];
static int32_t r269[400];
static int32_t r270[400];
static int32_t r271[6400];
static int32_t r272[6400];
static int32_t r273[400];
static int32_t r274[6400];
static int32_t r275[400];
static int32_t r276[6400];
static int32_t r277[6400];
static int32_t r278[400];
static int32_t r279[400];
static uint8_t r280[400];
static int32_t r281[400];
static int32_t r282[400];
static int32_t r283[1];
static int32_t r284[400];
static int32_t r285[400];
static int32_t r286[6400];
static int32_t r287[400];
static int32_t r288[400];
static int32_t r289[6400];
static int32_t r290[1];
static int32_t r291[1];
static int32_t r292[400];
static int32_t r293[400];
static int32_t r294[1];
static int32_t r295[400];
static int32_t r296[400];
static int32_t r297[400];
static int32_t r298[6400];
static int32_t r299[6400];
static int32_t r300[400];
static int32_t r301[6400];
static int32_t r302[400];
static int32_t r303[6400];
static int32_t r304[6400];
static int32_t r305[400];
static int32_t r306[400];
static uint8_t r307[400];
static int32_t r308[400];
static int32_t r309[400];
static int32_t r310[1];
static int32_t r311[400];
static int32_t r312[400];
static int32_t r313[400];
static int32_t r314[400];
static int32_t r315[1];
static int32_t r316[400];
static int32_t r317[400];
static int32_t r318[1];
static uint8_t r319[400];
static int32_t r320[1];
static int32_t r321[400];
static int32_t r322[400];
static int32_t r323[5];
static int32_t r324[5];
static uint8_t r325[1];
static int32_t r326[1];
static int32_t r327[1];
static int32_t r328[1];
static int32_t r329[15];
static int32_t r330[1];
static int32_t r331[1];
static int32_t r332[85];
static int32_t r333[85];
static int32_t r334[1];
static int32_t r335[86];
static int32_t r336[40];
static int32_t r337[40];
static int32_t r338[40];
static int32_t r339[6];
static int32_t r340[6];
static int32_t r341[240];
static int32_t r342[240];
static int32_t r343[1];
static int32_t r344[240];
static uint8_t r345[240];
static const int32_t *const r346 = rom18_lit;
static int32_t r347[240];
static int32_t r348[240];
static int32_t r349[240];
static int32_t r350[240];
static int32_t r351[6];
static int32_t r352[6];
static int32_t r353[240];
static int32_t r354[1];
static int32_t r355[240];
static int32_t r356[1];
static int32_t r357[240];
static int32_t r358[6];
static int32_t r359[240];
static int32_t r360[1];
static int32_t r361[240];
static int32_t r362[1];
static int32_t r363[240];
static int32_t r364[240];
static int32_t r365[40];
static int32_t r366[40];
static int32_t r367[240];
static int32_t r368[1];
static int32_t r369[1];
static int32_t r370[40];
static int32_t r371[40];
static int32_t r372[1];
static int32_t r373[40];
static int32_t r374[40];
static int32_t r375[40];
static int32_t r376[240];
static int32_t r377[240];
static int32_t r378[40];
static int32_t r379[240];
static int32_t r380[40];
static int32_t r381[240];
static int32_t r382[240];
static int32_t r383[40];
static int32_t r384[40];
static uint8_t r385[40];
static int32_t r386[40];
static int32_t r387[40];
static int32_t r388[1];
static int32_t r389[40];
static int32_t r390[40];
static int32_t r391[240];
static int32_t r392[40];
static int32_t r393[40];
static int32_t r394[240];
static int32_t r395[1];
static int32_t r396[1];
static int32_t r397[40];
static int32_t r398[40];
static int32_t r399[1];
static int32_t r400[40];
static int32_t r401[40];
static int32_t r402[40];
static int32_t r403[240];
static int32_t r404[240];
static int32_t r405[40];
static int32_t r406[240];
static int32_t r407[40];
static int32_t r408[240];
static int32_t r409[240];
static int32_t r410[40];
static int32_t r411[40];
static uint8_t r412[40];
static int32_t r413[40];
static int32_t r414[40];
static int32_t r415[1];
static int32_t r416[40];
static int32_t r417[40];
static int32_t r418[40];
static int32_t r419[40];
static int32_t r420[1];
static int32_t r421[40];
static int32_t r422[1];
static int32_t r423[40];
static int32_t r424[1];
static int32_t r425[1];
static int32_t r426[1];
static int32_t r427[1];
static int32_t r428[55];
static int32_t r429[55];
static int32_t r430[80];
static int32_t r431[80];
static int32_t r432[80];
static int32_t r433[40];
static int32_t r434[40];
static int32_t r435[16];
static int32_t r436[16];
static int32_t r437[640];
static uint8_t r438[640];
static const int32_t *const r439 = rom19_lit;
static int32_t r440[640];
static int32_t r441[640];
static int32_t r442[640];
static int32_t r443[640];
static int32_t r444[640];
static int32_t r445[3200];
static int32_t r446[1];
static int32_t r447[3200];
static int32_t r448[1];
static int32_t r449[3200];
static int32_t r450[3200];
static int32_t r451[1];
static int32_t r452[3200];
static int32_t r453[1];
static int32_t r454[3200];
static int32_t r455[3200];
static int32_t r456[200];
static int32_t r457[200];
static int32_t r458[3200];
static int32_t r459[1];
static int32_t r460[1];
static int32_t r461[200];
static int32_t r462[200];
static int32_t r463[1];
static int32_t r464[200];
static int32_t r465[200];
static int32_t r466[200];
static int32_t r467[3200];
static int32_t r468[3200];
static int32_t r469[200];
static int32_t r470[3200];
static int32_t r471[200];
static int32_t r472[3200];
static int32_t r473[3200];
static int32_t r474[200];
static int32_t r475[200];
static uint8_t r476[200];
static int32_t r477[200];
static int32_t r478[200];
static int32_t r479[1];
static int32_t r480[200];
static int32_t r481[200];
static int32_t r482[3200];
static int32_t r483[200];
static int32_t r484[200];
static int32_t r485[3200];
static int32_t r486[1];
static int32_t r487[1];
static int32_t r488[200];
static int32_t r489[200];
static int32_t r490[1];
static int32_t r491[200];
static int32_t r492[200];
static int32_t r493[200];
static int32_t r494[3200];
static int32_t r495[3200];
static int32_t r496[200];
static int32_t r497[3200];
static int32_t r498[200];
static int32_t r499[3200];
static int32_t r500[3200];
static int32_t r501[200];
static int32_t r502[200];
static uint8_t r503[200];
static int32_t r504[200];
static int32_t r505[200];
static int32_t r506[1];
static int32_t r507[200];
static int32_t r508[200];
static int32_t r509[200];
static int32_t r510[200];
static int32_t r511[1];
static int32_t r512[200];
static int32_t r513[200];
static int32_t r514[1];
static uint8_t r515[200];
static int32_t r516[1];
static int32_t r517[200];
static int32_t r518[200];
static int32_t r519[5];
static const int32_t *const r520 = rom20_lit;
static int32_t r521[5];
static uint8_t r522[1];
static int32_t r523[1];
static int32_t r524[1];
static int32_t r525[1];
static int32_t r526[15];
static int32_t r527[1];
static int32_t r528[1];
static int32_t r529[45];
static int32_t r530[45];
static int32_t r531[1];
static int32_t r532[46];
static int32_t r533[20];
static int32_t r534[20];
static int32_t r535[20];
static int32_t r536[6];
static int32_t r537[6];
static int32_t r538[120];
static int32_t r539[120];
static int32_t r540[1];
static int32_t r541[120];
static uint8_t r542[120];
static const int32_t *const r543 = rom21_lit;
static int32_t r544[120];
static int32_t r545[120];
static int32_t r546[120];
static int32_t r547[120];
static int32_t r548[6];
static int32_t r549[6];
static int32_t r550[120];
static int32_t r551[1];
static int32_t r552[120];
static int32_t r553[1];
static int32_t r554[120];
static int32_t r555[6];
static int32_t r556[120];
static int32_t r557[1];
static int32_t r558[120];
static int32_t r559[1];
static int32_t r560[120];
static int32_t r561[120];
static int32_t r562[20];
static int32_t r563[20];
static int32_t r564[120];
static int32_t r565[1];
static int32_t r566[1];
static int32_t r567[20];
static int32_t r568[20];
static int32_t r569[1];
static int32_t r570[20];
static int32_t r571[20];
static int32_t r572[20];
static int32_t r573[120];
static int32_t r574[120];
static int32_t r575[20];
static int32_t r576[120];
static int32_t r577[20];
static int32_t r578[120];
static int32_t r579[120];
static int32_t r580[20];
static int32_t r581[20];
static uint8_t r582[20];
static int32_t r583[20];
static int32_t r584[20];
static int32_t r585[1];
static int32_t r586[20];
static int32_t r587[20];
static int32_t r588[120];
static int32_t r589[20];
static int32_t r590[20];
static int32_t r591[120];
static int32_t r592[1];
static int32_t r593[1];
static int32_t r594[20];
static int32_t r595[20];
static int32_t r596[1];
static int32_t r597[20];
static int32_t r598[20];
static int32_t r599[20];
static int32_t r600[120];
static int32_t r601[120];
static int32_t r602[20];
static int32_t r603[120];
static int32_t r604[20];
static int32_t r605[120];
static int32_t r606[120];
static int32_t r607[20];
static int32_t r608[20];
static uint8_t r609[20];
static int32_t r610[20];
static int32_t r611[20];
static int32_t r612[1];
static int32_t r613[20];
static int32_t r614[20];
static int32_t r615[20];
static int32_t r616[20];
static int32_t r617[1];
static int32_t r618[20];
static int32_t r619[1];
static int32_t r620[20];
static int32_t r621[1];
static int32_t r622[1];
static int32_t r623[1];
static int32_t r624[1];
static int32_t r625[35];
static int32_t r626[35];
static int32_t r627[80];
static int32_t r628[80];
static int32_t r629[80];
static int32_t r630[20];
static int32_t r631[20];
static int32_t r632[16];
static int32_t r633[16];
static int32_t r634[320];
static uint8_t r635[320];
static const int32_t *const r636 = rom22_lit;
static int32_t r637[320];
static int32_t r638[320];
static int32_t r639[320];
static int32_t r640[320];
static int32_t r641[320];
static int32_t r642[1600];
static int32_t r643[1];
static int32_t r644[1600];
static int32_t r645[1];
static int32_t r646[1600];
static int32_t r647[1600];
static int32_t r648[1];
static int32_t r649[1600];
static int32_t r650[1];
static int32_t r651[1600];
static int32_t r652[1600];
static int32_t r653[100];
static int32_t r654[100];
static int32_t r655[1600];
static int32_t r656[1];
static int32_t r657[1];
static int32_t r658[100];
static int32_t r659[100];
static int32_t r660[1];
static int32_t r661[100];
static int32_t r662[100];
static int32_t r663[100];
static int32_t r664[1600];
static int32_t r665[1600];
static int32_t r666[100];
static int32_t r667[1600];
static int32_t r668[100];
static int32_t r669[1600];
static int32_t r670[1600];
static int32_t r671[100];
static int32_t r672[100];
static uint8_t r673[100];
static int32_t r674[100];
static int32_t r675[100];
static int32_t r676[1];
static int32_t r677[100];
static int32_t r678[100];
static int32_t r679[1600];
static int32_t r680[100];
static int32_t r681[100];
static int32_t r682[1600];
static int32_t r683[1];
static int32_t r684[1];
static int32_t r685[100];
static int32_t r686[100];
static int32_t r687[1];
static int32_t r688[100];
static int32_t r689[100];
static int32_t r690[100];
static int32_t r691[1600];
static int32_t r692[1600];
static int32_t r693[100];
static int32_t r694[1600];
static int32_t r695[100];
static int32_t r696[1600];
static int32_t r697[1600];
static int32_t r698[100];
static int32_t r699[100];
static uint8_t r700[100];
static int32_t r701[100];
static int32_t r702[100];
static int32_t r703[1];
static int32_t r704[100];
static int32_t r705[100];
static int32_t r706[100];
static int32_t r707[100];
static int32_t r708[1];
static int32_t r709[100];
static int32_t r710[100];
static int32_t r711[1];
static uint8_t r712[100];
static int32_t r713[1];
static int32_t r714[100];
static int32_t r715[100];
static int32_t r716[5];
static const int32_t *const r717 = rom23_lit;
static int32_t r718[5];
static uint8_t r719[1];
static int32_t r720[1];
static int32_t r721[1];
static int32_t r722[1];
static int32_t r723[15];
static int32_t r724[1];
static int32_t r725[1];
static int32_t r726[25];
static int32_t r727[25];
static int32_t r728[1];
static int32_t r729[26];
static int32_t r730[10];
static int32_t r731[10];
static int32_t r732[10];
static int32_t r733[6];
static int32_t r734[6];
static int32_t r735[60];
static int32_t r736[60];
static int32_t r737[1];
static int32_t r738[60];
static uint8_t r739[60];
static const int32_t *const r740 = rom24_lit;
static int32_t r741[60];
static int32_t r742[60];
static int32_t r743[60];
static int32_t r744[60];
static int32_t r745[6];
static int32_t r746[6];
static int32_t r747[60];
static int32_t r748[1];
static int32_t r749[60];
static int32_t r750[1];
static int32_t r751[60];
static int32_t r752[6];
static int32_t r753[60];
static int32_t r754[1];
static int32_t r755[60];
static int32_t r756[1];
static int32_t r757[60];
static int32_t r758[60];
static int32_t r759[10];
static int32_t r760[10];
static int32_t r761[60];
static int32_t r762[1];
static int32_t r763[1];
static int32_t r764[10];
static int32_t r765[10];
static int32_t r766[1];
static int32_t r767[10];
static int32_t r768[10];
static int32_t r769[10];
static int32_t r770[60];
static int32_t r771[60];
static int32_t r772[10];
static int32_t r773[60];
static int32_t r774[10];
static int32_t r775[60];
static int32_t r776[60];
static int32_t r777[10];
static int32_t r778[10];
static uint8_t r779[10];
static int32_t r780[10];
static int32_t r781[10];
static int32_t r782[1];
static int32_t r783[10];
static int32_t r784[10];
static int32_t r785[60];
static int32_t r786[10];
static int32_t r787[10];
static int32_t r788[60];
static int32_t r789[1];
static int32_t r790[1];
static int32_t r791[10];
static int32_t r792[10];
static int32_t r793[1];
static int32_t r794[10];
static int32_t r795[10];
static int32_t r796[10];
static int32_t r797[60];
static int32_t r798[60];
static int32_t r799[10];
static int32_t r800[60];
static int32_t r801[10];
static int32_t r802[60];
static int32_t r803[60];
static int32_t r804[10];
static int32_t r805[10];
static uint8_t r806[10];
static int32_t r807[10];
static int32_t r808[10];
static int32_t r809[1];
static int32_t r810[10];
static int32_t r811[10];
static int32_t r812[10];
static int32_t r813[10];
static int32_t r814[1];
static int32_t r815[10];
static int32_t r816[1];
static int32_t r817[10];
static int32_t r818[1];
static int32_t r819[1];
static int32_t r820[1];
static int32_t r821[1];
static int32_t r822[25];
static int32_t r823[25];
static int32_t r824[80];
static int32_t r825[80];
static int32_t r826[80];
static int32_t r827[10];
static int32_t r828[10];
static int32_t r829[16];
static int32_t r830[16];
static int32_t r831[160];
static uint8_t r832[160];
static const int32_t *const r833 = rom25_lit;
static int32_t r834[160];
static int32_t r835[160];
static int32_t r836[160];
static int32_t r837[160];
static int32_t r838[160];
static int32_t r839[800];
static int32_t r840[1];
static int32_t r841[800];
static int32_t r842[1];
static int32_t r843[800];
static int32_t r844[800];
static int32_t r845[1];
static int32_t r846[800];
static int32_t r847[1];
static int32_t r848[800];
static int32_t r849[800];
static int32_t r850[50];
static int32_t r851[50];
static int32_t r852[800];
static int32_t r853[1];
static int32_t r854[1];
static int32_t r855[50];
static int32_t r856[50];
static int32_t r857[1];
static int32_t r858[50];
static int32_t r859[50];
static int32_t r860[50];
static int32_t r861[800];
static int32_t r862[800];
static int32_t r863[50];
static int32_t r864[800];
static int32_t r865[50];
static int32_t r866[800];
static int32_t r867[800];
static int32_t r868[50];
static int32_t r869[50];
static uint8_t r870[50];
static int32_t r871[50];
static int32_t r872[50];
static int32_t r873[1];
static int32_t r874[50];
static int32_t r875[50];
static int32_t r876[800];
static int32_t r877[50];
static int32_t r878[50];
static int32_t r879[800];
static int32_t r880[1];
static int32_t r881[1];
static int32_t r882[50];
static int32_t r883[50];
static int32_t r884[1];
static int32_t r885[50];
static int32_t r886[50];
static int32_t r887[50];
static int32_t r888[800];
static int32_t r889[800];
static int32_t r890[50];
static int32_t r891[800];
static int32_t r892[50];
static int32_t r893[800];
static int32_t r894[800];
static int32_t r895[50];
static int32_t r896[50];
static uint8_t r897[50];
static int32_t r898[50];
static int32_t r899[50];
static int32_t r900[1];
static int32_t r901[50];
static int32_t r902[50];
static int32_t r903[50];
static int32_t r904[50];
static int32_t r905[1];
static int32_t r906[50];
static int32_t r907[50];
static int32_t r908[1];
static uint8_t r909[50];
static int32_t r910[1];
static int32_t r911[50];
static int32_t r912[50];
static int32_t r913[5];
static const int32_t *const r914 = rom26_lit;
static int32_t r915[5];
static uint8_t r916[1];
static int32_t r917[1];
static int32_t r918[1];
static int32_t r919[1];
static int32_t r920[15];
static int32_t r921[1];
static int32_t r922[1];
static int32_t r923[15];
static int32_t r924[15];
static int32_t r925[1];
static int32_t r926[16];
static int32_t r927[5];
static int32_t r928[5];
static int32_t r929[5];
static int32_t r930[6];
static int32_t r931[6];
static int32_t r932[30];
static int32_t r933[30];
static int32_t r934[1];
static int32_t r935[30];
static uint8_t r936[30];
static const int32_t *const r937 = rom27_lit;
static int32_t r938[30];
static int32_t r939[30];
static int32_t r940[30];
static int32_t r941[30];
static int32_t r942[6];
static int32_t r943[6];
static int32_t r944[30];
static int32_t r945[1];
static int32_t r946[30];
static int32_t r947[1];
static int32_t r948[30];
static int32_t r949[6];
static int32_t r950[30];
static int32_t r951[1];
static int32_t r952[30];
static int32_t r953[1];
static int32_t r954[30];
static int32_t r955[30];
static int32_t r956[5];
static int32_t r957[5];
static int32_t r958[30];
static int32_t r959[1];
static int32_t r960[1];
static int32_t r961[5];
static int32_t r962[5];
static int32_t r963[1];
static int32_t r964[5];
static int32_t r965[5];
static int32_t r966[5];
static int32_t r967[30];
static int32_t r968[30];
static int32_t r969[5];
static int32_t r970[30];
static int32_t r971[5];
static int32_t r972[30];
static int32_t r973[30];
static int32_t r974[5];
static int32_t r975[5];
static uint8_t r976[5];
static int32_t r977[5];
static int32_t r978[5];
static int32_t r979[1];
static int32_t r980[5];
static int32_t r981[5];
static int32_t r982[30];
static int32_t r983[5];
static int32_t r984[5];
static int32_t r985[30];
static int32_t r986[1];
static int32_t r987[1];
static int32_t r988[5];
static int32_t r989[5];
static int32_t r990[1];
static int32_t r991[5];
static int32_t r992[5];
static int32_t r993[5];
static int32_t r994[30];
static int32_t r995[30];
static int32_t r996[5];
static int32_t r997[30];
static int32_t r998[5];
static int32_t r999[30];
static int32_t r1000[30];
static int32_t r1001[5];
static int32_t r1002[5];
static uint8_t r1003[5];
static int32_t r1004[5];
static int32_t r1005[5];
static int32_t r1006[1];
static int32_t r1007[5];
static int32_t r1008[5];
static int32_t r1009[5];
static int32_t r1010[5];
static int32_t r1011[1];
static int32_t r1012[5];
static int32_t r1013[1];
static int32_t r1014[5];
static int32_t r1015[1];
static int32_t r1016[1];
static int32_t r1017[1];
static int32_t r1018[1];
static int32_t r1019[20];
static int32_t r1020[20];
static int32_t r1021[80];
static int32_t r1022[80];
static int32_t r1023[80];
static int32_t r1024[5];
static int32_t r1025[5];
static int32_t r1026[16];
static int32_t r1027[16];
static int32_t r1028[80];
static uint8_t r1029[80];
static const int32_t *const r1030 = rom28_lit;
static int32_t r1031[80];
static int32_t r1032[80];
static int32_t r1033[80];
static int32_t r1034[80];
static int32_t r1035[80];
static int32_t r1036[400];
static int32_t r1037[1];
static int32_t r1038[400];
static int32_t r1039[1];
static int32_t r1040[400];
static int32_t r1041[400];
static int32_t r1042[1];
static int32_t r1043[400];
static int32_t r1044[1];
static int32_t r1045[400];
static int32_t r1046[400];
static int32_t r1047[25];
static int32_t r1048[25];
static int32_t r1049[400];
static int32_t r1050[1];
static int32_t r1051[1];
static int32_t r1052[25];
static int32_t r1053[25];
static int32_t r1054[1];
static int32_t r1055[25];
static int32_t r1056[25];
static int32_t r1057[25];
static int32_t r1058[400];
static int32_t r1059[400];
static int32_t r1060[25];
static int32_t r1061[400];
static int32_t r1062[25];
static int32_t r1063[400];
static int32_t r1064[400];
static int32_t r1065[25];
static int32_t r1066[25];
static uint8_t r1067[25];
static int32_t r1068[25];
static int32_t r1069[25];
static int32_t r1070[1];
static int32_t r1071[25];
static int32_t r1072[25];
static int32_t r1073[400];
static int32_t r1074[25];
static int32_t r1075[25];
static int32_t r1076[400];
static int32_t r1077[1];
static int32_t r1078[1];
static int32_t r1079[25];
static int32_t r1080[25];
static int32_t r1081[1];
static int32_t r1082[25];
static int32_t r1083[25];
static int32_t r1084[25];
static int32_t r1085[400];
static int32_t r1086[400];
static int32_t r1087[25];
static int32_t r1088[400];
static int32_t r1089[25];
static int32_t r1090[400];
static int32_t r1091[400];
static int32_t r1092[25];
static int32_t r1093[25];
static uint8_t r1094[25];
static int32_t r1095[25];
static int32_t r1096[25];
static int32_t r1097[1];
static int32_t r1098[25];
static int32_t r1099[25];
static int32_t r1100[25];
static int32_t r1101[25];
static int32_t r1102[1];
static int32_t r1103[25];
static int32_t r1104[25];
static int32_t r1105[1];
static uint8_t r1106[25];
static int32_t r1107[1];
static int32_t r1108[25];
static int32_t r1109[25];
static int32_t r1110[5];
static const int32_t *const r1111 = rom29_lit;
static int32_t r1112[5];
static uint8_t r1113[1];
static int32_t r1114[1];
static int32_t r1115[1];
static int32_t r1116[1];
static int32_t r1117[15];
static int32_t r1118[1];
static int32_t r1119[30];
static int32_t r1120[30];
static int32_t r1121[1];
static int32_t r1122[30];
static int32_t r1123[30];
static int32_t r1124[30];
static int32_t r1125[30];
static uint8_t r1126[30];
static int32_t r1127[30];
static int32_t r1128[30];
static int32_t r1129[30];
static int32_t r1130[30];
static int32_t r1131[30];
static int32_t r1132[30];
static int32_t r1133[30];
static uint8_t r1134[30];
static int32_t r1135[30];
static int32_t r1136[30];
static uint8_t r1137[30];
static int32_t r1138[30];
static int32_t r1139[30];
static int32_t r1140[30];
static int32_t r1141[30];
static int32_t r1142[30];
static int32_t r1143[30];
static int32_t r1144[30];
static uint8_t r1145[30];
static int32_t r1146[30];
static int32_t r1147[30];
static uint8_t r1148[30];
static int32_t r1149[30];
static uint8_t r1150[30];
static int32_t r1151[30];
static uint8_t r1152[30];
static int32_t r1153[30];
static uint8_t r1154[30];
static int32_t r1155[30];
static int32_t r1156[1];
static int32_t r1157[30];
static int32_t r1158[1];
static int32_t r1159[30];
static int32_t r1160[30];
static int32_t r1161[30];
static int32_t r1162[30];
static int32_t r1163[30];
static int32_t r1164[300];
static int32_t r1165[300];
static int32_t r1166[300];
static int32_t r1167[300];
static int32_t r1168[1];
static int32_t r1169[300];
static int32_t r1170[1];
static int32_t r1171[300];
static int32_t r1172[300];
static int32_t r1173[300];
static int32_t r1174[1];
static int32_t r1175[300];
static int32_t r1176[1];
static int32_t r1177[300];
static int32_t r1178[600];
static int32_t r1179[10];
static int32_t r1180[10];
static int32_t r1181[610];
static int32_t r1182[610];
static int32_t r1183[10];
static const int32_t *const r1184 = rom30_lit;
static int32_t r1185[10];
static int32_t r1186[610];
static int32_t r1187[1];
static int32_t r1188[1];
static int32_t r1189[10];
static int32_t r1190[10];
static int32_t r1191[1];
static int32_t r1192[10];
static int32_t r1193[10];
static int32_t r1194[10];
static int32_t r1195[610];
static int32_t r1196[610];
static int32_t r1197[10];
static uint8_t r1198[10];
static int32_t r1199[10];
static int32_t r1200[10];
static int32_t r1201[1];
static int32_t r1202[10];
static int32_t r1203[10];
static int32_t r1204[300];
static int32_t r1205[300];
static int32_t r1206[1];
static int32_t r1207[300];
static int32_t r1208[1];
static int32_t r1209[300];
static int32_t r1210[300];
static int32_t r1211[300];
static int32_t r1212[1];
static int32_t r1213[300];
static int32_t r1214[1];
static int32_t r1215[300];
static int32_t r1216[600];
static int32_t r1217[10];
static int32_t r1218[10];
static int32_t r1219[610];
static int32_t r1220[610];
static int32_t r1221[10];
static int32_t r1222[10];
static int32_t r1223[610];
static int32_t r1224[1];
static int32_t r1225[1];
static int32_t r1226[10];
static int32_t r1227[10];
static int32_t r1228[1];
static int32_t r1229[10];
static int32_t r1230[10];
static int32_t r1231[10];
static int32_t r1232[610];
static int32_t r1233[610];
static int32_t r1234[10];
static uint8_t r1235[10];
static int32_t r1236[10];
static int32_t r1237[10];
static int32_t r1238[1];
static int32_t r1239[10];
static int32_t r1240[10];
static int32_t r1241[10];
static int32_t r1242[10];
static int32_t r1243[20];
static int32_t r1244[10];
static const int32_t *const r1245 = rom31_lit;
static int32_t r1246[10];
static int32_t r1247[20];
static int32_t r1248[1];
static int32_t r1249[1];
static int32_t r1250[10];
static int32_t r1251[10];
static int32_t r1252[1];
static int32_t r1253[10];
static int32_t r1254[10];
static int32_t r1255[10];
static int32_t r1256[20];
static int32_t r1257[20];
static int32_t r1258[10];
static uint8_t r1259[10];
static int32_t r1260[10];
static int32_t r1261[10];
static int32_t r1262[1];
static int32_t r1263[10];
static int32_t r1264[10];
static int32_t r1265[10];
static int32_t r1266[10];
static int32_t r1267[10];
static int32_t r1268[10];
static int32_t r1269[10];

static void program_run(void) {
    /* abs [abs] -> r26 */
    for (long i1 = 0; i1 < 160; ++i1) {
        r26[i1] = abs32(r16[i1]);
    }
    /* reduce_max [reduce_max] -> r27 */
    for (long i2 = 0; i2 < 1; ++i2) {
        r27[i2] = (-2147483647 - 1);
    }
    for (long i3 = 0; i3 < 160; ++i3) {
        long t5 = i3;
        long c40 = t5 / 160; t5 %= 160;
        long c41 = t5;
        r27[c40 * 1] = max32(r27[c40 * 1], r26[i3]);
    }
    /* max [max] -> r28 */
    for (long i6 = 0; i6 < 1; ++i6) {
        r28[i6] = max32(r13[i6], r27[i6]);
    }
    /* concat [concatenate] -> r29 */
    for (long i7 = 0; i7 < 15; ++i7) {
        long t9 = i7;
        long c80 = t9 / 15; t9 %= 15;
        long c81 = t9;
        r29[c80 * 175 + (c81 + 0) * 1] = r0[i7];
    }
    for (long i10 = 0; i10 < 160; ++i10) {
        long t12 = i10;
        long c110 = t12 / 160; t12 %= 160;
        long c111 = t12;
        r29[c110 * 175 + (c111 + 15) * 1] = r16[i10];
    }
    /* shl [shift_left] -> r31 */
    for (long i13 = 0; i13 < 175; ++i13) {
        r31[i13] = shl32(r29[i13], 1);
    }
    /* mov [device_put] -> r32 */
    memcpy(r32, r18, sizeof(int32_t) * 80);
    /* rev [rev] -> r33 */
    for (long i14 = 0; i14 < 80; ++i14) {
        long t16 = i14;
        long c150 = t16 / 16; t16 %= 16;
        long c151 = t16;
        r33[i14] = r32[c150 * 16 + (16 - 1 - c151) * 1];
    }
    /* reshape [reshape] -> r34 */
    memcpy(r34, r33, sizeof(int32_t) * 80);
    /* iota [iota] -> r35 */
    for (long i17 = 0; i17 < 160; ++i17) {
        long t19 = i17;
        long c180 = t19;
        r35[i17] = (int32_t)c180;
    }
    /* broadcast [broadcast_in_dim] -> r36 */
    for (long i20 = 0; i20 < 160; ++i20) {
        long t22 = i20;
        long c210 = t22 / 1; t22 %= 1;
        long c211 = t22;
        r36[i20] = r35[c210 * 1];
    }
    /* iota [iota] -> r37 */
    for (long i23 = 0; i23 < 16; ++i23) {
        long t25 = i23;
        long c240 = t25;
        r37[i23] = (int32_t)c240;
    }
    /* broadcast [broadcast_in_dim] -> r38 */
    for (long i26 = 0; i26 < 16; ++i26) {
        long t28 = i26;
        long c270 = t28 / 16; t28 %= 16;
        long c271 = t28;
        r38[i26] = r37[c271 * 1];
    }
    /* add [add] -> r39 */
    for (long i29 = 0; i29 < 2560; ++i29) {
        long t31 = i29;
        long c300 = t31 / 16; t31 %= 16;
        long c301 = t31;
        r39[i29] = add32(r36[c300 * 1], r38[c301 * 1]);
    }
    /* lt [lt] -> r41 */
    for (long i32 = 0; i32 < 2560; ++i32) {
        r41[i32] = r39[i32] < r40[0] ? 1 : 0;
    }
    /* add [add] -> r43 */
    for (long i33 = 0; i33 < 2560; ++i33) {
        r43[i33] = add32(r39[i33], r42[0]);
    }
    /* select_n [select_n] -> r44 */
    for (long i34 = 0; i34 < 2560; ++i34) {
        r44[i34] = r41[i34] == 0 ? r39[i34] : (r43[i34]);
    }
    /* broadcast [broadcast_in_dim] -> r45 */
    for (long i35 = 0; i35 < 2560; ++i35) {
        long t37 = i35;
        long c360 = t37 / 16; t37 %= 16;
        long c361 = t37 / 1; t37 %= 1;
        long c362 = t37;
        r45[i35] = r44[c360 * 16 + c361 * 1];
    }
    /* gather [gather] -> r46 */
    for (long i38 = 0; i38 < 2560; ++i38) {
        long t40 = i38;
        long c390 = t40 / 2560; t40 %= 2560;
        long c391 = t40 / 16; t40 %= 16;
        long c392 = t40;
        long row41 = c391 * 16 + c392 * 1;
        long s42 = clamp_start((long)r45[row41 + 0], 175, 1);
        r46[i38] = r31[c390 * 175 + s42 * 1];
    }
    /* broadcast [broadcast_in_dim] -> r47 */
    for (long i43 = 0; i43 < 2560; ++i43) {
        long t45 = i43;
        long c440 = t45 / 2560; t45 %= 2560;
        long c441 = t45 / 2560; t45 %= 2560;
        long c442 = t45 / 16; t45 %= 16;
        long c443 = t45;
        r47[i43] = r46[c442 * 16 + c443 * 1];
    }
    /* add [add] -> r48 */
    for (long i46 = 0; i46 < 12800; ++i46) {
        long t48 = i46;
        long c470 = t48 / 2560; t48 %= 2560;
        long c471 = t48 / 2560; t48 %= 2560;
        long c472 = t48 / 16; t48 %= 16;
        long c473 = t48;
        r48[i46] = add32(r34[c470 * 16 + c473 * 1], r47[c472 * 16 + c473 * 1]);
    }
    /* convert [convert_element_type] -> r51 */
    for (long i49 = 0; i49 < 1; ++i49) {
        r51[i49] = (int32_t)r49[0];
    }
    /* max [max] -> r52 */
    for (long i50 = 0; i50 < 12800; ++i50) {
        r52[i50] = max32(r51[0], r48[i50]);
    }
    /* convert [convert_element_type] -> r53 */
    for (long i51 = 0; i51 < 1; ++i51) {
        r53[i51] = (int32_t)r50[0];
    }
    /* min [min] -> r54 */
    for (long i52 = 0; i52 < 12800; ++i52) {
        r54[i52] = min32(r53[0], r52[i52]);
    }
    /* sub [sub] -> r55 */
    for (long i53 = 0; i53 < 12800; ++i53) {
        long t55 = i53;
        long c540 = t55 / 2560; t55 %= 2560;
        long c541 = t55 / 2560; t55 %= 2560;
        long c542 = t55 / 16; t55 %= 16;
        long c543 = t55;
        r55[i53] = sub32(r34[c540 * 16 + c543 * 1], r47[c542 * 16 + c543 * 1]);
    }
    /* convert [convert_element_type] -> r56 */
    for (long i56 = 0; i56 < 1; ++i56) {
        r56[i56] = (int32_t)r49[0];
    }
    /* max [max] -> r57 */
    for (long i57 = 0; i57 < 12800; ++i57) {
        r57[i57] = max32(r56[0], r55[i57]);
    }
    /* convert [convert_element_type] -> r58 */
    for (long i58 = 0; i58 < 1; ++i58) {
        r58[i58] = (int32_t)r50[0];
    }
    /* min [min] -> r59 */
    for (long i59 = 0; i59 < 12800; ++i59) {
        r59[i59] = min32(r58[0], r57[i59]);
    }
    /* abs [abs] -> r60 */
    for (long i60 = 0; i60 < 12800; ++i60) {
        r60[i60] = abs32(r54[i60]);
    }
    /* reduce_max [reduce_max] -> r61 */
    for (long i61 = 0; i61 < 800; ++i61) {
        r61[i61] = (-2147483647 - 1);
    }
    for (long i62 = 0; i62 < 12800; ++i62) {
        long t64 = i62;
        long c630 = t64 / 2560; t64 %= 2560;
        long c631 = t64 / 2560; t64 %= 2560;
        long c632 = t64 / 16; t64 %= 16;
        long c633 = t64;
        r61[c630 * 160 + c631 * 160 + c632 * 1] = max32(r61[c630 * 160 + c631 * 160 + c632 * 1], r60[i62]);
    }
    /* sub [sub] -> r63 */
    for (long i65 = 0; i65 < 800; ++i65) {
        r63[i65] = sub32(r61[i65], r62[0]);
    }
    /* loop [scan] -> r85 */
    memcpy(r64, r54, sizeof(int32_t) * 12800);
    memcpy(r65, r62, sizeof(int32_t) * 1);
    memcpy(r66, r40, sizeof(int32_t) * 1);
    memcpy(r67, r63, sizeof(int32_t) * 800);
    memcpy(r68, r61, sizeof(int32_t) * 800);
    for (long t66 = 0; t66 < 12; ++t66) {
        /* add [add] -> r69 */
        for (long i1067 = 0; i1067 < 1; ++i1067) {
            r69[i1067] = add32(r66[0], r30[0]);
        }
        /* add [add] -> r70 */
        for (long i1068 = 0; i1068 < 800; ++i1068) {
            r70[i1068] = add32(r67[i1068], r68[i1068]);
        }
        /* shra [shift_right_arithmetic] -> r71 */
        for (long i1069 = 0; i1069 < 800; ++i1069) {
            r71[i1069] = asr32(r70[i1069], 1);
        }
        /* broadcast [broadcast_in_dim] -> r72 */
        for (long i1070 = 0; i1070 < 800; ++i1070) {
            long t1072 = i1070;
            long c10710 = t1072 / 160; t1072 %= 160;
            long c10711 = t1072 / 160; t1072 %= 160;
            long c10712 = t1072 / 1; t1072 %= 1;
            long c10713 = t1072;
            r72[i1070] = r71[c10710 * 160 + c10712 * 1];
        }
        /* sub [sub] -> r73 */
        for (long i1073 = 0; i1073 < 12800; ++i1073) {
            long t1075 = i1073;
            long c10740 = t1075 / 2560; t1075 %= 2560;
            long c10741 = t1075 / 2560; t1075 %= 2560;
            long c10742 = t1075 / 16; t1075 %= 16;
            long c10743 = t1075;
            r73[i1073] = sub32(r64[c10740 * 2560 + c10742 * 16 + c10743 * 1], r72[c10740 * 160 + c10742 * 1]);
        }
        /* max [max] -> r74 */
        for (long i1076 = 0; i1076 < 12800; ++i1076) {
            r74[i1076] = max32(r73[i1076], r40[0]);
        }
        /* reduce_sum [reduce_sum] -> r75 */
        for (long i1077 = 0; i1077 < 800; ++i1077) {
            r75[i1077] = 0;
        }
        for (long i1078 = 0; i1078 < 12800; ++i1078) {
            long t1080 = i1078;
            long c10790 = t1080 / 2560; t1080 %= 2560;
            long c10791 = t1080 / 2560; t1080 %= 2560;
            long c10792 = t1080 / 16; t1080 %= 16;
            long c10793 = t1080;
            r75[c10790 * 160 + c10791 * 160 + c10792 * 1] = add32(r75[c10790 * 160 + c10791 * 160 + c10792 * 1], r74[i1078]);
        }
        /* neg [neg] -> r76 */
        for (long i1081 = 0; i1081 < 12800; ++i1081) {
            r76[i1081] = neg32(r64[i1081]);
        }
        /* broadcast [broadcast_in_dim] -> r77 */
        for (long i1082 = 0; i1082 < 800; ++i1082) {
            long t1084 = i1082;
            long c10830 = t1084 / 160; t1084 %= 160;
            long c10831 = t1084 / 160; t1084 %= 160;
            long c10832 = t1084 / 1; t1084 %= 1;
            long c10833 = t1084;
            r77[i1082] = r71[c10830 * 160 + c10832 * 1];
        }
        /* sub [sub] -> r78 */
        for (long i1085 = 0; i1085 < 12800; ++i1085) {
            long t1087 = i1085;
            long c10860 = t1087 / 2560; t1087 %= 2560;
            long c10861 = t1087 / 2560; t1087 %= 2560;
            long c10862 = t1087 / 16; t1087 %= 16;
            long c10863 = t1087;
            r78[i1085] = sub32(r76[c10860 * 2560 + c10862 * 16 + c10863 * 1], r77[c10860 * 160 + c10862 * 1]);
        }
        /* max [max] -> r79 */
        for (long i1088 = 0; i1088 < 12800; ++i1088) {
            r79[i1088] = max32(r78[i1088], r40[0]);
        }
        /* reduce_sum [reduce_sum] -> r80 */
        for (long i1089 = 0; i1089 < 800; ++i1089) {
            r80[i1089] = 0;
        }
        for (long i1090 = 0; i1090 < 12800; ++i1090) {
            long t1092 = i1090;
            long c10910 = t1092 / 2560; t1092 %= 2560;
            long c10911 = t1092 / 2560; t1092 %= 2560;
            long c10912 = t1092 / 16; t1092 %= 16;
            long c10913 = t1092;
            r80[c10910 * 160 + c10911 * 160 + c10912 * 1] = add32(r80[c10910 * 160 + c10911 * 160 + c10912 * 1], r79[i1090]);
        }
        /* add [add] -> r81 */
        for (long i1093 = 0; i1093 < 800; ++i1093) {
            r81[i1093] = add32(r75[i1093], r80[i1093]);
        }
        /* gt [gt] -> r82 */
        for (long i1094 = 0; i1094 < 800; ++i1094) {
            r82[i1094] = r81[i1094] > r65[0] ? 1 : 0;
        }
        /* select_n [select_n] -> r83 */
        for (long i1095 = 0; i1095 < 800; ++i1095) {
            r83[i1095] = r82[i1095] == 0 ? r67[i1095] : (r71[i1095]);
        }
        /* select_n [select_n] -> r84 */
        for (long i1096 = 0; i1096 < 800; ++i1096) {
            r84[i1096] = r82[i1096] == 0 ? r71[i1096] : (r68[i1096]);
        }
        memcpy(r66, r69, sizeof(int32_t) * 1);
        memcpy(r67, r83, sizeof(int32_t) * 800);
        memcpy(r68, r84, sizeof(int32_t) * 800);
    }
    memcpy(r85, r66, sizeof(int32_t) * 1);
    memcpy(r86, r67, sizeof(int32_t) * 800);
    memcpy(r87, r68, sizeof(int32_t) * 800);
    /* abs [abs] -> r88 */
    for (long i1097 = 0; i1097 < 12800; ++i1097) {
        r88[i1097] = abs32(r59[i1097]);
    }
    /* reduce_max [reduce_max] -> r89 */
    for (long i1098 = 0; i1098 < 800; ++i1098) {
        r89[i1098] = (-2147483647 - 1);
    }
    for (long i1099 = 0; i1099 < 12800; ++i1099) {
        long t1101 = i1099;
        long c11000 = t1101 / 2560; t1101 %= 2560;
        long c11001 = t1101 / 2560; t1101 %= 2560;
        long c11002 = t1101 / 16; t1101 %= 16;
        long c11003 = t1101;
        r89[c11000 * 160 + c11001 * 160 + c11002 * 1] = max32(r89[c11000 * 160 + c11001 * 160 + c11002 * 1], r88[i1099]);
    }
    /* sub [sub] -> r90 */
    for (long i1102 = 0; i1102 < 800; ++i1102) {
        r90[i1102] = sub32(r89[i1102], r62[0]);
    }
    /* loop [scan] -> r112 */
    memcpy(r91, r59, sizeof(int32_t) * 12800);
    memcpy(r92, r62, sizeof(int32_t) * 1);
    memcpy(r93, r40, sizeof(int32_t) * 1);
    memcpy(r94, r90, sizeof(int32_t) * 800);
    memcpy(r95, r89, sizeof(int32_t) * 800);
    for (long t1103 = 0; t1103 < 12; ++t1103) {
        /* add [add] -> r96 */
        for (long i2104 = 0; i2104 < 1; ++i2104) {
            r96[i2104] = add32(r93[0], r30[0]);
        }
        /* add [add] -> r97 */
        for (long i2105 = 0; i2105 < 800; ++i2105) {
            r97[i2105] = add32(r94[i2105], r95[i2105]);
        }
        /* shra [shift_right_arithmetic] -> r98 */
        for (long i2106 = 0; i2106 < 800; ++i2106) {
            r98[i2106] = asr32(r97[i2106], 1);
        }
        /* broadcast [broadcast_in_dim] -> r99 */
        for (long i2107 = 0; i2107 < 800; ++i2107) {
            long t2109 = i2107;
            long c21080 = t2109 / 160; t2109 %= 160;
            long c21081 = t2109 / 160; t2109 %= 160;
            long c21082 = t2109 / 1; t2109 %= 1;
            long c21083 = t2109;
            r99[i2107] = r98[c21080 * 160 + c21082 * 1];
        }
        /* sub [sub] -> r100 */
        for (long i2110 = 0; i2110 < 12800; ++i2110) {
            long t2112 = i2110;
            long c21110 = t2112 / 2560; t2112 %= 2560;
            long c21111 = t2112 / 2560; t2112 %= 2560;
            long c21112 = t2112 / 16; t2112 %= 16;
            long c21113 = t2112;
            r100[i2110] = sub32(r91[c21110 * 2560 + c21112 * 16 + c21113 * 1], r99[c21110 * 160 + c21112 * 1]);
        }
        /* max [max] -> r101 */
        for (long i2113 = 0; i2113 < 12800; ++i2113) {
            r101[i2113] = max32(r100[i2113], r40[0]);
        }
        /* reduce_sum [reduce_sum] -> r102 */
        for (long i2114 = 0; i2114 < 800; ++i2114) {
            r102[i2114] = 0;
        }
        for (long i2115 = 0; i2115 < 12800; ++i2115) {
            long t2117 = i2115;
            long c21160 = t2117 / 2560; t2117 %= 2560;
            long c21161 = t2117 / 2560; t2117 %= 2560;
            long c21162 = t2117 / 16; t2117 %= 16;
            long c21163 = t2117;
            r102[c21160 * 160 + c21161 * 160 + c21162 * 1] = add32(r102[c21160 * 160 + c21161 * 160 + c21162 * 1], r101[i2115]);
        }
        /* neg [neg] -> r103 */
        for (long i2118 = 0; i2118 < 12800; ++i2118) {
            r103[i2118] = neg32(r91[i2118]);
        }
        /* broadcast [broadcast_in_dim] -> r104 */
        for (long i2119 = 0; i2119 < 800; ++i2119) {
            long t2121 = i2119;
            long c21200 = t2121 / 160; t2121 %= 160;
            long c21201 = t2121 / 160; t2121 %= 160;
            long c21202 = t2121 / 1; t2121 %= 1;
            long c21203 = t2121;
            r104[i2119] = r98[c21200 * 160 + c21202 * 1];
        }
        /* sub [sub] -> r105 */
        for (long i2122 = 0; i2122 < 12800; ++i2122) {
            long t2124 = i2122;
            long c21230 = t2124 / 2560; t2124 %= 2560;
            long c21231 = t2124 / 2560; t2124 %= 2560;
            long c21232 = t2124 / 16; t2124 %= 16;
            long c21233 = t2124;
            r105[i2122] = sub32(r103[c21230 * 2560 + c21232 * 16 + c21233 * 1], r104[c21230 * 160 + c21232 * 1]);
        }
        /* max [max] -> r106 */
        for (long i2125 = 0; i2125 < 12800; ++i2125) {
            r106[i2125] = max32(r105[i2125], r40[0]);
        }
        /* reduce_sum [reduce_sum] -> r107 */
        for (long i2126 = 0; i2126 < 800; ++i2126) {
            r107[i2126] = 0;
        }
        for (long i2127 = 0; i2127 < 12800; ++i2127) {
            long t2129 = i2127;
            long c21280 = t2129 / 2560; t2129 %= 2560;
            long c21281 = t2129 / 2560; t2129 %= 2560;
            long c21282 = t2129 / 16; t2129 %= 16;
            long c21283 = t2129;
            r107[c21280 * 160 + c21281 * 160 + c21282 * 1] = add32(r107[c21280 * 160 + c21281 * 160 + c21282 * 1], r106[i2127]);
        }
        /* add [add] -> r108 */
        for (long i2130 = 0; i2130 < 800; ++i2130) {
            r108[i2130] = add32(r102[i2130], r107[i2130]);
        }
        /* gt [gt] -> r109 */
        for (long i2131 = 0; i2131 < 800; ++i2131) {
            r109[i2131] = r108[i2131] > r92[0] ? 1 : 0;
        }
        /* select_n [select_n] -> r110 */
        for (long i2132 = 0; i2132 < 800; ++i2132) {
            r110[i2132] = r109[i2132] == 0 ? r94[i2132] : (r98[i2132]);
        }
        /* select_n [select_n] -> r111 */
        for (long i2133 = 0; i2133 < 800; ++i2133) {
            r111[i2133] = r109[i2133] == 0 ? r98[i2133] : (r95[i2133]);
        }
        memcpy(r93, r96, sizeof(int32_t) * 1);
        memcpy(r94, r110, sizeof(int32_t) * 800);
        memcpy(r95, r111, sizeof(int32_t) * 800);
    }
    memcpy(r112, r93, sizeof(int32_t) * 1);
    memcpy(r113, r94, sizeof(int32_t) * 800);
    memcpy(r114, r95, sizeof(int32_t) * 800);
    /* sub [sub] -> r115 */
    for (long i2134 = 0; i2134 < 800; ++i2134) {
        r115[i2134] = sub32(r87[i2134], r114[i2134]);
    }
    /* transpose [transpose] -> r116 */
    for (long i2135 = 0; i2135 < 800; ++i2135) {
        long t2137 = i2135;
        long c21360 = t2137 / 800; t2137 %= 800;
        long c21361 = t2137 / 160; t2137 %= 160;
        long c21362 = t2137;
        r116[i2135] = r115[c21360 * 160 + c21361 * 160 + c21362 * 1];
    }
    /* broadcast [broadcast_in_dim] -> r117 */
    for (long i2138 = 0; i2138 < 1; ++i2138) {
        long t2140 = i2138;
        long c21390 = t2140 / 1; t2140 %= 1;
        long c21391 = t2140;
        r117[i2138] = r17[0];
    }
    /* max [max] -> r118 */
    for (long i2141 = 0; i2141 < 800; ++i2141) {
        r118[i2141] = max32(r116[i2141], r40[0]);
    }
    /* iota [iota] -> r119 */
    for (long i2142 = 0; i2142 < 800; ++i2142) {
        long t2144 = i2142;
        long c21430 = t2144 / 800; t2144 %= 800;
        long c21431 = t2144 / 160; t2144 %= 160;
        long c21432 = t2144;
        r119[i2142] = (int32_t)c21432;
    }
    /* broadcast [broadcast_in_dim] -> r120 */
    for (long i2145 = 0; i2145 < 1; ++i2145) {
        long t2147 = i2145;
        long c21460 = t2147 / 1; t2147 %= 1;
        long c21461 = t2147 / 1; t2147 %= 1;
        long c21462 = t2147;
        r120[i2145] = r117[0];
    }
    /* lt [lt] -> r121 */
    for (long i2148 = 0; i2148 < 800; ++i2148) {
        long t2150 = i2148;
        long c21490 = t2150 / 800; t2150 %= 800;
        long c21491 = t2150 / 160; t2150 %= 160;
        long c21492 = t2150;
        r121[i2148] = r119[c21491 * 160 + c21492 * 1] < r120[0] ? 1 : 0;
    }
    /* convert [convert_element_type] -> r122 */
    for (long i2151 = 0; i2151 < 1; ++i2151) {
        r122[i2151] = (int32_t)r40[0];
    }
    /* broadcast [broadcast_in_dim] -> r123 */
    for (long i2152 = 0; i2152 < 800; ++i2152) {
        long t2154 = i2152;
        long c21530 = t2154 / 800; t2154 %= 800;
        long c21531 = t2154 / 160; t2154 %= 160;
        long c21532 = t2154;
        r123[i2152] = r122[0];
    }
    /* select_n [select_n] -> r124 */
    for (long i2155 = 0; i2155 < 800; ++i2155) {
        r124[i2155] = r121[i2155] == 0 ? r123[i2155] : (r118[i2155]);
    }
    /* reduce_sum [reduce_sum] -> r125 */
    for (long i2156 = 0; i2156 < 5; ++i2156) {
        r125[i2156] = 0;
    }
    for (long i2157 = 0; i2157 < 800; ++i2157) {
        long t2159 = i2157;
        long c21580 = t2159 / 800; t2159 %= 800;
        long c21581 = t2159 / 160; t2159 %= 160;
        long c21582 = t2159;
        r125[c21580 * 5 + c21581 * 1] = add32(r125[c21580 * 5 + c21581 * 1], r124[i2157]);
    }
    /* shl [shift_left] -> r126 */
    for (long i2160 = 0; i2160 < 5; ++i2160) {
        r126[i2160] = shl32(r125[i2160], 0);
    }
    /* lt [lt] -> r127 */
    for (long i2161 = 0; i2161 < 1; ++i2161) {
        r127[i2161] = r17[i2161] < r40[0] ? 1 : 0;
    }
    /* add [add] -> r128 */
    for (long i2162 = 0; i2162 < 1; ++i2162) {
        r128[i2162] = add32(r17[i2162], r42[0]);
    }
    /* select_n [select_n] -> r129 */
    for (long i2163 = 0; i2163 < 1; ++i2163) {
        r129[i2163] = r127[i2163] == 0 ? r17[i2163] : (r128[i2163]);
    }
    /* broadcast [broadcast_in_dim] -> r130 */
    for (long i2164 = 0; i2164 < 1; ++i2164) {
        long t2166 = i2164;
        long c21650 = t2166 / 1; t2166 %= 1;
        long c21651 = t2166;
        r130[i2164] = r129[0];
    }
    /* gather [gather] -> r131 */
    for (long i2167 = 0; i2167 < 15; ++i2167) {
        long t2169 = i2167;
        long c21680 = t2169 / 15; t2169 %= 15;
        long c21681 = t2169;
        long row2170 = c21680 * 1;
        long s2171 = clamp_start((long)r130[row2170 + 0], 175, 15);
        r131[i2167] = r29[c21680 * 175 + (s2171 + c21681) * 1];
    }
    /* add [add] -> r132 */
    for (long i2172 = 0; i2172 < 1; ++i2172) {
        r132[i2172] = add32(r6[i2172], r17[i2172]);
    }
    /* and [and] -> r133 */
    for (long i2173 = 0; i2173 < 1; ++i2173) {
        r133[i2173] = r6[i2173] & r30[0];
    }
    /* slice [slice] -> r134 */
    for (long i2174 = 0; i2174 < 165; ++i2174) {
        long t2176 = i2174;
        long c21750 = t2176 / 165; t2176 %= 165;
        long c21751 = t2176;
        r134[i2174] = r29[(0 + c21750 * 1) * 175 + (10 + c21751 * 1) * 1];
    }
    /* shl [shift_left] -> r135 */
    for (long i2177 = 0; i2177 < 165; ++i2177) {
        r135[i2177] = shl32(r134[i2177], 1);
    }
    /* convert [convert_element_type] -> r136 */
    for (long i2178 = 0; i2178 < 1; ++i2178) {
        r136[i2178] = (int32_t)r40[0];
    }
    /* pad [pad] -> r137 */
    for (long i2179 = 0; i2179 < 166; ++i2179) {
        r137[i2179] = r136[0];
    }
    for (long i2180 = 0; i2180 < 165; ++i2180) {
        long t2182 = i2180;
        long c21810 = t2182 / 165; t2182 %= 165;
        long c21811 = t2182;
        long d2183 = 0 + c21810 * 1;
        long d2184 = 0 + c21811 * 1;
        if (d2183 >= 0 && d2183 < 1 && d2184 >= 0 && d2184 < 166) r137[d2183 * 166 + d2184 * 1] = r135[i2180];
    }
    /* iota [iota] -> r138 */
    for (long i2185 = 0; i2185 < 80; ++i2185) {
        long t2187 = i2185;
        long c21860 = t2187;
        r138[i2185] = (int32_t)c21860;
    }
    /* shl [shift_left] -> r139 */
    for (long i2188 = 0; i2188 < 80; ++i2188) {
        r139[i2188] = shl32(r138[i2188], 1);
    }
    /* broadcast [broadcast_in_dim] -> r140 */
    for (long i2189 = 0; i2189 < 80; ++i2189) {
        long t2191 = i2189;
        long c21900 = t2191 / 1; t2191 %= 1;
        long c21901 = t2191;
        r140[i2189] = r139[c21900 * 1];
    }
    /* iota [iota] -> r141 */
    for (long i2192 = 0; i2192 < 6; ++i2192) {
        long t2194 = i2192;
        long c21930 = t2194;
        r141[i2192] = (int32_t)c21930;
    }
    /* broadcast [broadcast_in_dim] -> r142 */
    for (long i2195 = 0; i2195 < 6; ++i2195) {
        long t2197 = i2195;
        long c21960 = t2197 / 6; t2197 %= 6;
        long c21961 = t2197;
        r142[i2195] = r141[c21961 * 1];
    }
    /* add [add] -> r143 */
    for (long i2198 = 0; i2198 < 480; ++i2198) {
        long t2200 = i2198;
        long c21990 = t2200 / 6; t2200 %= 6;
        long c21991 = t2200;
        r143[i2198] = add32(r140[c21990 * 1], r142[c21991 * 1]);
    }
    /* broadcast [broadcast_in_dim] -> r144 */
    for (long i2201 = 0; i2201 < 480; ++i2201) {
        long t2203 = i2201;
        long c22020 = t2203 / 480; t2203 %= 480;
        long c22021 = t2203 / 6; t2203 %= 6;
        long c22022 = t2203;
        r144[i2201] = r143[c22021 * 6 + c22022 * 1];
    }
    /* broadcast [broadcast_in_dim] -> r145 */
    for (long i2204 = 0; i2204 < 1; ++i2204) {
        long t2206 = i2204;
        long c22050 = t2206 / 1; t2206 %= 1;
        long c22051 = t2206 / 1; t2206 %= 1;
        long c22052 = t2206;
        r145[i2204] = r133[0];
    }
    /* add [add] -> r146 */
    for (long i2207 = 0; i2207 < 480; ++i2207) {
        long t2209 = i2207;
        long c22080 = t2209 / 480; t2209 %= 480;
        long c22081 = t2209 / 6; t2209 %= 6;
        long c22082 = t2209;
        r146[i2207] = add32(r145[0], r144[c22081 * 6 + c22082 * 1]);
    }
    /* lt [lt] -> r147 */
    for (long i2210 = 0; i2210 < 480; ++i2210) {
        r147[i2210] = r146[i2210] < r40[0] ? 1 : 0;
    }
    /* add [add] -> r149 */
    for (long i2211 = 0; i2211 < 480; ++i2211) {
        r149[i2211] = add32(r146[i2211], r148[0]);
    }
    /* select_n [select_n] -> r150 */
    for (long i2212 = 0; i2212 < 480; ++i2212) {
        r150[i2212] = r147[i2212] == 0 ? r146[i2212] : (r149[i2212]);
    }
    /* broadcast [broadcast_in_dim] -> r151 */
    for (long i2213 = 0; i2213 < 480; ++i2213) {
        long t2215 = i2213;
        long c22140 = t2215 / 480; t2215 %= 480;
        long c22141 = t2215 / 6; t2215 %= 6;
        long c22142 = t2215 / 1; t2215 %= 1;
        long c22143 = t2215;
        r151[i2213] = r150[c22141 * 6 + c22142 * 1];
    }
    /* gather [gather] -> r152 */
    for (long i2216 = 0; i2216 < 480; ++i2216) {
        long t2218 = i2216;
        long c22170 = t2218 / 480; t2218 %= 480;
        long c22171 = t2218 / 6; t2218 %= 6;
        long c22172 = t2218;
        long row2219 = c22170 * 480 + c22171 * 6 + c22172 * 1;
        long s2220 = clamp_start((long)r151[row2219 + 0], 166, 1);
        r152[i2216] = r137[c22170 * 166 + s2220 * 1];
    }
    /* mov [device_put] -> r153 */
    memcpy(r153, r19, sizeof(int32_t) * 6);
    /* broadcast [broadcast_in_dim] -> r154 */
    for (long i2221 = 0; i2221 < 6; ++i2221) {
        long t2223 = i2221;
        long c22220 = t2223 / 6; t2223 %= 6;
        long c22221 = t2223 / 6; t2223 %= 6;
        long c22222 = t2223;
        r154[i2221] = r153[c22222 * 1];
    }
    /* add [add] -> r155 */
    for (long i2224 = 0; i2224 < 480; ++i2224) {
        long t2226 = i2224;
        long c22250 = t2226 / 480; t2226 %= 480;
        long c22251 = t2226 / 6; t2226 %= 6;
        long c22252 = t2226;
        r155[i2224] = add32(r154[c22252 * 1], r152[c22251 * 6 + c22252 * 1]);
    }
    /* convert [convert_element_type] -> r156 */
    for (long i2227 = 0; i2227 < 1; ++i2227) {
        r156[i2227] = (int32_t)r49[0];
    }
    /* max [max] -> r157 */
    for (long i2228 = 0; i2228 < 480; ++i2228) {
        r157[i2228] = max32(r156[0], r155[i2228]);
    }
    /* convert [convert_element_type] -> r158 */
    for (long i2229 = 0; i2229 < 1; ++i2229) {
        r158[i2229] = (int32_t)r50[0];
    }
    /* min [min] -> r159 */
    for (long i2230 = 0; i2230 < 480; ++i2230) {
        r159[i2230] = min32(r158[0], r157[i2230]);
    }
    /* broadcast [broadcast_in_dim] -> r160 */
    for (long i2231 = 0; i2231 < 6; ++i2231) {
        long t2233 = i2231;
        long c22320 = t2233 / 6; t2233 %= 6;
        long c22321 = t2233 / 6; t2233 %= 6;
        long c22322 = t2233;
        r160[i2231] = r153[c22322 * 1];
    }
    /* sub [sub] -> r161 */
    for (long i2234 = 0; i2234 < 480; ++i2234) {
        long t2236 = i2234;
        long c22350 = t2236 / 480; t2236 %= 480;
        long c22351 = t2236 / 6; t2236 %= 6;
        long c22352 = t2236;
        r161[i2234] = sub32(r160[c22352 * 1], r152[c22351 * 6 + c22352 * 1]);
    }
    /* convert [convert_element_type] -> r162 */
    for (long i2237 = 0; i2237 < 1; ++i2237) {
        r162[i2237] = (int32_t)r49[0];
    }
    /* max [max] -> r163 */
    for (long i2238 = 0; i2238 < 480; ++i2238) {
        r163[i2238] = max32(r162[0], r161[i2238]);
    }
    /* convert [convert_element_type] -> r164 */
    for (long i2239 = 0; i2239 < 1; ++i2239) {
        r164[i2239] = (int32_t)r50[0];
    }
    /* min [min] -> r165 */
    for (long i2240 = 0; i2240 < 480; ++i2240) {
        r165[i2240] = min32(r164[0], r163[i2240]);
    }
    /* abs [abs] -> r166 */
    for (long i2241 = 0; i2241 < 480; ++i2241) {
        r166[i2241] = abs32(r159[i2241]);
    }
    /* reduce_max [reduce_max] -> r167 */
    for (long i2242 = 0; i2242 < 80; ++i2242) {
        r167[i2242] = (-2147483647 - 1);
    }
    for (long i2243 = 0; i2243 < 480; ++i2243) {
        long t2245 = i2243;
        long c22440 = t2245 / 480; t2245 %= 480;
        long c22441 = t2245 / 6; t2245 %= 6;
        long c22442 = t2245;
        r167[c22440 * 80 + c22441 * 1] = max32(r167[c22440 * 80 + c22441 * 1], r166[i2243]);
    }
    /* sub [sub] -> r168 */
    for (long i2246 = 0; i2246 < 80; ++i2246) {
        r168[i2246] = sub32(r167[i2246], r62[0]);
    }
    /* loop [scan] -> r190 */
    memcpy(r169, r159, sizeof(int32_t) * 480);
    memcpy(r170, r62, sizeof(int32_t) * 1);
    memcpy(r171, r40, sizeof(int32_t) * 1);
    memcpy(r172, r168, sizeof(int32_t) * 80);
    memcpy(r173, r167, sizeof(int32_t) * 80);
    for (long t2247 = 0; t2247 < 12; ++t2247) {
        /* add [add] -> r174 */
        for (long i3248 = 0; i3248 < 1; ++i3248) {
            r174[i3248] = add32(r171[0], r30[0]);
        }
        /* add [add] -> r175 */
        for (long i3249 = 0; i3249 < 80; ++i3249) {
            r175[i3249] = add32(r172[i3249], r173[i3249]);
        }
        /* shra [shift_right_arithmetic] -> r176 */
        for (long i3250 = 0; i3250 < 80; ++i3250) {
            r176[i3250] = asr32(r175[i3250], 1);
        }
        /* broadcast [broadcast_in_dim] -> r177 */
        for (long i3251 = 0; i3251 < 80; ++i3251) {
            long t3253 = i3251;
            long c32520 = t3253 / 80; t3253 %= 80;
            long c32521 = t3253 / 1; t3253 %= 1;
            long c32522 = t3253;
            r177[i3251] = r176[c32521 * 1];
        }
        /* sub [sub] -> r178 */
        for (long i3254 = 0; i3254 < 480; ++i3254) {
            long t3256 = i3254;
            long c32550 = t3256 / 480; t3256 %= 480;
            long c32551 = t3256 / 6; t3256 %= 6;
            long c32552 = t3256;
            r178[i3254] = sub32(r169[c32551 * 6 + c32552 * 1], r177[c32551 * 1]);
        }
        /* max [max] -> r179 */
        for (long i3257 = 0; i3257 < 480; ++i3257) {
            r179[i3257] = max32(r178[i3257], r40[0]);
        }
        /* reduce_sum [reduce_sum] -> r180 */
        for (long i3258 = 0; i3258 < 80; ++i3258) {
            r180[i3258] = 0;
        }
        for (long i3259 = 0; i3259 < 480; ++i3259) {
            long t3261 = i3259;
            long c32600 = t3261 / 480; t3261 %= 480;
            long c32601 = t3261 / 6; t3261 %= 6;
            long c32602 = t3261;
            r180[c32600 * 80 + c32601 * 1] = add32(r180[c32600 * 80 + c32601 * 1], r179[i3259]);
        }
        /* neg [neg] -> r181 */
        for (long i3262 = 0; i3262 < 480; ++i3262) {
            r181[i3262] = neg32(r169[i3262]);
        }
        /* broadcast [broadcast_in_dim] -> r182 */
        for (long i3263 = 0; i3263 < 80; ++i3263) {
            long t3265 = i3263;
            long c32640 = t3265 / 80; t3265 %= 80;
            long c32641 = t3265 / 1; t3265 %= 1;
            long c32642 = t3265;
            r182[i3263] = r176[c32641 * 1];
        }
        /* sub [sub] -> r183 */
        for (long i3266 = 0; i3266 < 480; ++i3266) {
            long t3268 = i3266;
            long c32670 = t3268 / 480; t3268 %= 480;
            long c32671 = t3268 / 6; t3268 %= 6;
            long c32672 = t3268;
            r183[i3266] = sub32(r181[c32671 * 6 + c32672 * 1], r182[c32671 * 1]);
        }
        /* max [max] -> r184 */
        for (long i3269 = 0; i3269 < 480; ++i3269) {
            r184[i3269] = max32(r183[i3269], r40[0]);
        }
        /* reduce_sum [reduce_sum] -> r185 */
        for (long i3270 = 0; i3270 < 80; ++i3270) {
            r185[i3270] = 0;
        }
        for (long i3271 = 0; i3271 < 480; ++i3271) {
            long t3273 = i3271;
            long c32720 = t3273 / 480; t3273 %= 480;
            long c32721 = t3273 / 6; t3273 %= 6;
            long c32722 = t3273;
            r185[c32720 * 80 + c32721 * 1] = add32(r185[c32720 * 80 + c32721 * 1], r184[i3271]);
        }
        /* add [add] -> r186 */
        for (long i3274 = 0; i3274 < 80; ++i3274) {
            r186[i3274] = add32(r180[i3274], r185[i3274]);
        }
        /* gt [gt] -> r187 */
        for (long i3275 = 0; i3275 < 80; ++i3275) {
            r187[i3275] = r186[i3275] > r170[0] ? 1 : 0;
        }
        /* select_n [select_n] -> r188 */
        for (long i3276 = 0; i3276 < 80; ++i3276) {
            r188[i3276] = r187[i3276] == 0 ? r172[i3276] : (r176[i3276]);
        }
        /* select_n [select_n] -> r189 */
        for (long i3277 = 0; i3277 < 80; ++i3277) {
            r189[i3277] = r187[i3277] == 0 ? r176[i3277] : (r173[i3277]);
        }
        memcpy(r171, r174, sizeof(int32_t) * 1);
        memcpy(r172, r188, sizeof(int32_t) * 80);
        memcpy(r173, r189, sizeof(int32_t) * 80);
    }
    memcpy(r190, r171, sizeof(int32_t) * 1);
    memcpy(r191, r172, sizeof(int32_t) * 80);
    memcpy(r192, r173, sizeof(int32_t) * 80);
    /* abs [abs] -> r193 */
    for (long i3278 = 0; i3278 < 480; ++i3278) {
        r193[i3278] = abs32(r165[i3278]);
    }
    /* reduce_max [reduce_max] -> r194 */
    for (long i3279 = 0; i3279 < 80; ++i3279) {
        r194[i3279] = (-2147483647 - 1);
    }
    for (long i3280 = 0; i3280 < 480; ++i3280) {
        long t3282 = i3280;
        long c32810 = t3282 / 480; t3282 %= 480;
        long c32811 = t3282 / 6; t3282 %= 6;
        long c32812 = t3282;
        r194[c32810 * 80 + c32811 * 1] = max32(r194[c32810 * 80 + c32811 * 1], r193[i3280]);
    }
    /* sub [sub] -> r195 */
    for (long i3283 = 0; i3283 < 80; ++i3283) {
        r195[i3283] = sub32(r194[i3283], r62[0]);
    }
    /* loop [scan] -> r217 */
    memcpy(r196, r165, sizeof(int32_t) * 480);
    memcpy(r197, r62, sizeof(int32_t) * 1);
    memcpy(r198, r40, sizeof(int32_t) * 1);
    memcpy(r199, r195, sizeof(int32_t) * 80);
    memcpy(r200, r194, sizeof(int32_t) * 80);
    for (long t3284 = 0; t3284 < 12; ++t3284) {
        /* add [add] -> r201 */
        for (long i4285 = 0; i4285 < 1; ++i4285) {
            r201[i4285] = add32(r198[0], r30[0]);
        }
        /* add [add] -> r202 */
        for (long i4286 = 0; i4286 < 80; ++i4286) {
            r202[i4286] = add32(r199[i4286], r200[i4286]);
        }
        /* shra [shift_right_arithmetic] -> r203 */
        for (long i4287 = 0; i4287 < 80; ++i4287) {
            r203[i4287] = asr32(r202[i4287], 1);
        }
        /* broadcast [broadcast_in_dim] -> r204 */
        for (long i4288 = 0; i4288 < 80; ++i4288) {
            long t4290 = i4288;
            long c42890 = t4290 / 80; t4290 %= 80;
            long c42891 = t4290 / 1; t4290 %= 1;
            long c42892 = t4290;
            r204[i4288] = r203[c42891 * 1];
        }
        /* sub [sub] -> r205 */
        for (long i4291 = 0; i4291 < 480; ++i4291) {
            long t4293 = i4291;
            long c42920 = t4293 / 480; t4293 %= 480;
            long c42921 = t4293 / 6; t4293 %= 6;
            long c42922 = t4293;
            r205[i4291] = sub32(r196[c42921 * 6 + c42922 * 1], r204[c42921 * 1]);
        }
        /* max [max] -> r206 */
        for (long i4294 = 0; i4294 < 480; ++i4294) {
            r206[i4294] = max32(r205[i4294], r40[0]);
        }
        /* reduce_sum [reduce_sum] -> r207 */
        for (long i4295 = 0; i4295 < 80; ++i4295) {
            r207[i4295] = 0;
        }
        for (long i4296 = 0; i4296 < 480; ++i4296) {
            long t4298 = i4296;
            long c42970 = t4298 / 480; t4298 %= 480;
            long c42971 = t4298 / 6; t4298 %= 6;
            long c42972 = t4298;
            r207[c42970 * 80 + c42971 * 1] = add32(r207[c42970 * 80 + c42971 * 1], r206[i4296]);
        }
        /* neg [neg] -> r208 */
        for (long i4299 = 0; i4299 < 480; ++i4299) {
            r208[i4299] = neg32(r196[i4299]);
        }
        /* broadcast [broadcast_in_dim] -> r209 */
        for (long i4300 = 0; i4300 < 80; ++i4300) {
            long t4302 = i4300;
            long c43010 = t4302 / 80; t4302 %= 80;
            long c43011 = t4302 / 1; t4302 %= 1;
            long c43012 = t4302;
            r209[i4300] = r203[c43011 * 1];
        }
        /* sub [sub] -> r210 */
        for (long i4303 = 0; i4303 < 480; ++i4303) {
            long t4305 = i4303;
            long c43040 = t4305 / 480; t4305 %= 480;
            long c43041 = t4305 / 6; t4305 %= 6;
            long c43042 = t4305;
            r210[i4303] = sub32(r208[c43041 * 6 + c43042 * 1], r209[c43041 * 1]);
        }
        /* max [max] -> r211 */
        for (long i4306 = 0; i4306 < 480; ++i4306) {
            r211[i4306] = max32(r210[i4306], r40[0]);
        }
        /* reduce_sum [reduce_sum] -> r212 */
        for (long i4307 = 0; i4307 < 80; ++i4307) {
            r212[i4307] = 0;
        }
        for (long i4308 = 0; i4308 < 480; ++i4308) {
            long t4310 = i4308;
            long c43090 = t4310 / 480; t4310 %= 480;
            long c43091 = t4310 / 6; t4310 %= 6;
            long c43092 = t4310;
            r212[c43090 * 80 + c43091 * 1] = add32(r212[c43090 * 80 + c43091 * 1], r211[i4308]);
        }
        /* add [add] -> r213 */
        for (long i4311 = 0; i4311 < 80; ++i4311) {
            r213[i4311] = add32(r207[i4311], r212[i4311]);
        }
        /* gt [gt] -> r214 */
        for (long i4312 = 0; i4312 < 80; ++i4312) {
            r214[i4312] = r213[i4312] > r197[0] ? 1 : 0;
        }
        /* select_n [select_n] -> r215 */
        for (long i4313 = 0; i4313 < 80; ++i4313) {
            r215[i4313] = r214[i4313] == 0 ? r199[i4313] : (r203[i4313]);
        }
        /* select_n [select_n] -> r216 */
        for (long i4314 = 0; i4314 < 80; ++i4314) {
            r216[i4314] = r214[i4314] == 0 ? r203[i4314] : (r200[i4314]);
        }
        memcpy(r198, r201, sizeof(int32_t) * 1);
        memcpy(r199, r215, sizeof(int32_t) * 80);
        memcpy(r200, r216, sizeof(int32_t) * 80);
    }
    memcpy(r217, r198, sizeof(int32_t) * 1);
    memcpy(r218, r199, sizeof(int32_t) * 80);
    memcpy(r219, r200, sizeof(int32_t) * 80);
    /* sub [sub] -> r220 */
    for (long i4315 = 0; i4315 < 80; ++i4315) {
        r220[i4315] = sub32(r192[i4315], r219[i4315]);
    }
    /* shra [shift_right_arithmetic] -> r221 */
    for (long i4316 = 0; i4316 < 80; ++i4316) {
        r221[i4316] = asr32(r220[i4316], 1);
    }
    /* convert [convert_element_type] -> r224 */
    for (long i4317 = 0; i4317 < 1; ++i4317) {
        r224[i4317] = (int32_t)r222[0];
    }
    /* max [max] -> r225 */
    for (long i4318 = 0; i4318 < 80; ++i4318) {
        r225[i4318] = max32(r224[0], r221[i4318]);
    }
    /* convert [convert_element_type] -> r226 */
    for (long i4319 = 0; i4319 < 1; ++i4319) {
        r226[i4319] = (int32_t)r223[0];
    }
    /* min [min] -> r227 */
    for (long i4320 = 0; i4320 < 80; ++i4320) {
        r227[i4320] = min32(r226[0], r225[i4320]);
    }
    /* sub [sub] -> r228 */
    for (long i4321 = 0; i4321 < 1; ++i4321) {
        r228[i4321] = sub32(r17[i4321], r133[i4321]);
    }
    /* add [add] -> r229 */
    for (long i4322 = 0; i4322 < 1; ++i4322) {
        r229[i4322] = add32(r228[i4322], r30[0]);
    }
    /* max [max] -> r230 */
    for (long i4323 = 0; i4323 < 1; ++i4323) {
        r230[i4323] = max32(r229[i4323], r40[0]);
    }
    /* shra [shift_right_arithmetic] -> r231 */
    for (long i4324 = 0; i4324 < 1; ++i4324) {
        r231[i4324] = asr32(r230[i4324], 1);
    }
    /* concat [concatenate] -> r232 */
    for (long i4325 = 0; i4325 < 15; ++i4325) {
        long t4327 = i4325;
        long c43260 = t4327 / 15; t4327 %= 15;
        long c43261 = t4327;
        r232[c43260 * 95 + (c43261 + 0) * 1] = r1[i4325];
    }
    for (long i4328 = 0; i4328 < 80; ++i4328) {
        long t4330 = i4328;
        long c43290 = t4330 / 80; t4330 %= 80;
        long c43291 = t4330;
        r232[c43290 * 95 + (c43291 + 15) * 1] = r227[i4328];
    }
    /* shl [shift_left] -> r233 */
    for (long i4331 = 0; i4331 < 95; ++i4331) {
        r233[i4331] = shl32(r232[i4331], 1);
    }
    /* mov [device_put] -> r234 */
    memcpy(r234, r18, sizeof(int32_t) * 80);
    /* rev [rev] -> r235 */
    for (long i4332 = 0; i4332 < 80; ++i4332) {
        long t4334 = i4332;
        long c43330 = t4334 / 16; t4334 %= 16;
        long c43331 = t4334;
        r235[i4332] = r234[c43330 * 16 + (16 - 1 - c43331) * 1];
    }
    /* reshape [reshape] -> r236 */
    memcpy(r236, r235, sizeof(int32_t) * 80);
    /* iota [iota] -> r237 */
    for (long i4335 = 0; i4335 < 80; ++i4335) {
        long t4337 = i4335;
        long c43360 = t4337;
        r237[i4335] = (int32_t)c43360;
    }
    /* broadcast [broadcast_in_dim] -> r238 */
    for (long i4338 = 0; i4338 < 80; ++i4338) {
        long t4340 = i4338;
        long c43390 = t4340 / 1; t4340 %= 1;
        long c43391 = t4340;
        r238[i4338] = r237[c43390 * 1];
    }
    /* iota [iota] -> r239 */
    for (long i4341 = 0; i4341 < 16; ++i4341) {
        long t4343 = i4341;
        long c43420 = t4343;
        r239[i4341] = (int32_t)c43420;
    }
    /* broadcast [broadcast_in_dim] -> r240 */
    for (long i4344 = 0; i4344 < 16; ++i4344) {
        long t4346 = i4344;
        long c43450 = t4346 / 16; t4346 %= 16;
        long c43451 = t4346;
        r240[i4344] = r239[c43451 * 1];
    }
    /* add [add] -> r241 */
    for (long i4347 = 0; i4347 < 1280; ++i4347) {
        long t4349 = i4347;
        long c43480 = t4349 / 16; t4349 %= 16;
        long c43481 = t4349;
        r241[i4347] = add32(r238[c43480 * 1], r240[c43481 * 1]);
    }
    /* lt [lt] -> r242 */
    for (long i4350 = 0; i4350 < 1280; ++i4350) {
        r242[i4350] = r241[i4350] < r40[0] ? 1 : 0;
    }
    /* add [add] -> r244 */
    for (long i4351 = 0; i4351 < 1280; ++i4351) {
        r244[i4351] = add32(r241[i4351], r243[0]);
    }
    /* select_n [select_n] -> r245 */
    for (long i4352 = 0; i4352 < 1280; ++i4352) {
        r245[i4352] = r242[i4352] == 0 ? r241[i4352] : (r244[i4352]);
    }
    /* broadcast [broadcast_in_dim] -> r246 */
    for (long i4353 = 0; i4353 < 1280; ++i4353) {
        long t4355 = i4353;
        long c43540 = t4355 / 16; t4355 %= 16;
        long c43541 = t4355 / 1; t4355 %= 1;
        long c43542 = t4355;
        r246[i4353] = r245[c43540 * 16 + c43541 * 1];
    }
    /* gather [gather] -> r247 */
    for (long i4356 = 0; i4356 < 1280; ++i4356) {
        long t4358 = i4356;
        long c43570 = t4358 / 1280; t4358 %= 1280;
        long c43571 = t4358 / 16; t4358 %= 16;
        long c43572 = t4358;
        long row4359 = c43571 * 16 + c43572 * 1;
        long s4360 = clamp_start((long)r246[row4359 + 0], 95, 1);
        r247[i4356] = r233[c43570 * 95 + s4360 * 1];
    }
    /* broadcast [broadcast_in_dim] -> r248 */
    for (long i4361 = 0; i4361 < 1280; ++i4361) {
        long t4363 = i4361;
        long c43620 = t4363 / 1280; t4363 %= 1280;
        long c43621 = t4363 / 1280; t4363 %= 1280;
        long c43622 = t4363 / 16; t4363 %= 16;
        long c43623 = t4363;
        r248[i4361] = r247[c43622 * 16 + c43623 * 1];
    }
    /* add [add] -> r249 */
    for (long i4364 = 0; i4364 < 6400; ++i4364) {
        long t4366 = i4364;
        long c43650 = t4366 / 1280; t4366 %= 1280;
        long c43651 = t4366 / 1280; t4366 %= 1280;
        long c43652 = t4366 / 16; t4366 %= 16;
        long c43653 = t4366;
        r249[i4364] = add32(r236[c43650 * 16 + c43653 * 1], r248[c43652 * 16 + c43653 * 1]);
    }
    /* convert [convert_element_type] -> r250 */
    for (long i4367 = 0; i4367 < 1; ++i4367) {
        r250[i4367] = (int32_t)r49[0];
    }
    /* max [max] -> r251 */
    for (long i4368 = 0; i4368 < 6400; ++i4368) {
        r251[i4368] = max32(r250[0], r249[i4368]);
    }
    /* convert [convert_element_type] -> r252 */
    for (long i4369 = 0; i4369 < 1; ++i4369) {
        r252[i4369] = (int32_t)r50[0];
    }
    /* min [min] -> r253 */
    for (long i4370 = 0; i4370 < 6400; ++i4370) {
        r253[i4370] = min32(r252[0], r251[i4370]);
    }
    /* sub [sub] -> r254 */
    for (long i4371 = 0; i4371 < 6400; ++i4371) {
        long t4373 = i4371;
        long c43720 = t4373 / 1280; t4373 %= 1280;
        long c43721 = t4373 / 1280; t4373 %= 1280;
        long c43722 = t4373 / 16; t4373 %= 16;
        long c43723 = t4373;
        r254[i4371] = sub32(r236[c43720 * 16 + c43723 * 1], r248[c43722 * 16 + c43723 * 1]);
    }
    /* convert [convert_element_type] -> r255 */
    for (long i4374 = 0; i4374 < 1; ++i4374) {
        r255[i4374] = (int32_t)r49[0];
    }
    /* max [max] -> r256 */
    for (long i4375 = 0; i4375 < 6400; ++i4375) {
        r256[i4375] = max32(r255[0], r254[i4375]);
    }
    /* convert [convert_element_type] -> r257 */
    for (long i4376 = 0; i4376 < 1; ++i4376) {
        r257[i4376] = (int32_t)r50[0];
    }
    /* min [min] -> r258 */
    for (long i4377 = 0; i4377 < 6400; ++i4377) {
        r258[i4377] = min32(r257[0], r256[i4377]);
    }
    /* abs [abs] -> r259 */
    for (long i4378 = 0; i4378 < 6400; ++i4378) {
        r259[i4378] = abs32(r253[i4378]);
    }
    /* reduce_max [reduce_max] -> r260 */
    for (long i4379 = 0; i4379 < 400; ++i4379) {
        r260[i4379] = (-2147483647 - 1);
    }
    for (long i4380 = 0; i4380 < 6400; ++i4380) {
        long t4382 = i4380;
        long c43810 = t4382 / 1280; t4382 %= 1280;
        long c43811 = t4382 / 1280; t4382 %= 1280;
        long c43812 = t4382 / 16; t4382 %= 16;
        long c43813 = t4382;
        r260[c43810 * 80 + c43811 * 80 + c43812 * 1] = max32(r260[c43810 * 80 + c43811 * 80 + c43812 * 1], r259[i4380]);
    }
    /* sub [sub] -> r261 */
    for (long i4383 = 0; i4383 < 400; ++i4383) {
        r261[i4383] = sub32(r260[i4383], r62[0]);
    }
    /* loop [scan] -> r283 */
    memcpy(r262, r253, sizeof(int32_t) * 6400);
    memcpy(r263, r62, sizeof(int32_t) * 1);
    memcpy(r264, r40, sizeof(int32_t) * 1);
    memcpy(r265, r261, sizeof(int32_t) * 400);
    memcpy(r266, r260, sizeof(int32_t) * 400);
    for (long t4384 = 0; t4384 < 12; ++t4384) {
        /* add [add] -> r267 */
        for (long i5385 = 0; i5385 < 1; ++i5385) {
            r267[i5385] = add32(r264[0], r30[0]);
        }
        /* add [add] -> r268 */
        for (long i5386 = 0; i5386 < 400; ++i5386) {
            r268[i5386] = add32(r265[i5386], r266[i5386]);
        }
        /* shra [shift_right_arithmetic] -> r269 */
        for (long i5387 = 0; i5387 < 400; ++i5387) {
            r269[i5387] = asr32(r268[i5387], 1);
        }
        /* broadcast [broadcast_in_dim] -> r270 */
        for (long i5388 = 0; i5388 < 400; ++i5388) {
            long t5390 = i5388;
            long c53890 = t5390 / 80; t5390 %= 80;
            long c53891 = t5390 / 80; t5390 %= 80;
            long c53892 = t5390 / 1; t5390 %= 1;
            long c53893 = t5390;
            r270[i5388] = r269[c53890 * 80 + c53892 * 1];
        }
        /* sub [sub] -> r271 */
        for (long i5391 = 0; i5391 < 6400; ++i5391) {
            long t5393 = i5391;
            long c53920 = t5393 / 1280; t5393 %= 1280;
            long c53921 = t5393 / 1280; t5393 %= 1280;
            long c53922 = t5393 / 16; t5393 %= 16;
            long c53923 = t5393;
            r271[i5391] = sub32(r262[c53920 * 1280 + c53922 * 16 + c53923 * 1], r270[c53920 * 80 + c53922 * 1]);
        }
        /* max [max] -> r272 */
        for (long i5394 = 0; i5394 < 6400; ++i5394) {
            r272[i5394] = max32(r271[i5394], r40[0]);
        }
        /* reduce_sum [reduce_sum] -> r273 */
        for (long i5395 = 0; i5395 < 400; ++i5395) {
            r273[i5395] = 0;
        }
        for (long i5396 = 0; i5396 < 6400; ++i5396) {
            long t5398 = i5396;
            long c53970 = t5398 / 1280; t5398 %= 1280;
            long c53971 = t5398 / 1280; t5398 %= 1280;
            long c53972 = t5398 / 16; t5398 %= 16;
            long c53973 = t5398;
            r273[c53970 * 80 + c53971 * 80 + c53972 * 1] = add32(r273[c53970 * 80 + c53971 * 80 + c53972 * 1], r272[i5396]);
        }
        /* neg [neg] -> r274 */
        for (long i5399 = 0; i5399 < 6400; ++i5399) {
            r274[i5399] = neg32(r262[i5399]);
        }
        /* broadcast [broadcast_in_dim] -> r275 */
        for (long i5400 = 0; i5400 < 400; ++i5400) {
            long t5402 = i5400;
            long c54010 = t5402 / 80; t5402 %= 80;
            long c54011 = t5402 / 80; t5402 %= 80;
            long c54012 = t5402 / 1; t5402 %= 1;
            long c54013 = t5402;
            r275[i5400] = r269[c54010 * 80 + c54012 * 1];
        }
        /* sub [sub] -> r276 */
        for (long i5403 = 0; i5403 < 6400; ++i5403) {
            long t5405 = i5403;
            long c54040 = t5405 / 1280; t5405 %= 1280;
            long c54041 = t5405 / 1280; t5405 %= 1280;
            long c54042 = t5405 / 16; t5405 %= 16;
            long c54043 = t5405;
            r276[i5403] = sub32(r274[c54040 * 1280 + c54042 * 16 + c54043 * 1], r275[c54040 * 80 + c54042 * 1]);
        }
        /* max [max] -> r277 */
        for (long i5406 = 0; i5406 < 6400; ++i5406) {
            r277[i5406] = max32(r276[i5406], r40[0]);
        }
        /* reduce_sum [reduce_sum] -> r278 */
        for (long i5407 = 0; i5407 < 400; ++i5407) {
            r278[i5407] = 0;
        }
        for (long i5408 = 0; i5408 < 6400; ++i5408) {
            long t5410 = i5408;
            long c54090 = t5410 / 1280; t5410 %= 1280;
            long c54091 = t5410 / 1280; t5410 %= 1280;
            long c54092 = t5410 / 16; t5410 %= 16;
            long c54093 = t5410;
            r278[c54090 * 80 + c54091 * 80 + c54092 * 1] = add32(r278[c54090 * 80 + c54091 * 80 + c54092 * 1], r277[i5408]);
        }
        /* add [add] -> r279 */
        for (long i5411 = 0; i5411 < 400; ++i5411) {
            r279[i5411] = add32(r273[i5411], r278[i5411]);
        }
        /* gt [gt] -> r280 */
        for (long i5412 = 0; i5412 < 400; ++i5412) {
            r280[i5412] = r279[i5412] > r263[0] ? 1 : 0;
        }
        /* select_n [select_n] -> r281 */
        for (long i5413 = 0; i5413 < 400; ++i5413) {
            r281[i5413] = r280[i5413] == 0 ? r265[i5413] : (r269[i5413]);
        }
        /* select_n [select_n] -> r282 */
        for (long i5414 = 0; i5414 < 400; ++i5414) {
            r282[i5414] = r280[i5414] == 0 ? r269[i5414] : (r266[i5414]);
        }
        memcpy(r264, r267, sizeof(int32_t) * 1);
        memcpy(r265, r281, sizeof(int32_t) * 400);
        memcpy(r266, r282, sizeof(int32_t) * 400);
    }
    memcpy(r283, r264, sizeof(int32_t) * 1);
    memcpy(r284, r265, sizeof(int32_t) * 400);
    memcpy(r285, r266, sizeof(int32_t) * 400);
    /* abs [abs] -> r286 */
    for (long i5415 = 0; i5415 < 6400; ++i5415) {
        r286[i5415] = abs32(r258[i5415]);
    }
    /* reduce_max [reduce_max] -> r287 */
    for (long i5416 = 0; i5416 < 400; ++i5416) {
        r287[i5416] = (-2147483647 - 1);
    }
    for (long i5417 = 0; i5417 < 6400; ++i5417) {
        long t5419 = i5417;
        long c54180 = t5419 / 1280; t5419 %= 1280;
        long c54181 = t5419 / 1280; t5419 %= 1280;
        long c54182 = t5419 / 16; t5419 %= 16;
        long c54183 = t5419;
        r287[c54180 * 80 + c54181 * 80 + c54182 * 1] = max32(r287[c54180 * 80 + c54181 * 80 + c54182 * 1], r286[i5417]);
    }
    /* sub [sub] -> r288 */
    for (long i5420 = 0; i5420 < 400; ++i5420) {
        r288[i5420] = sub32(r287[i5420], r62[0]);
    }
    /* loop [scan] -> r310 */
    memcpy(r289, r258, sizeof(int32_t) * 6400);
    memcpy(r290, r62, sizeof(int32_t) * 1);
    memcpy(r291, r40, sizeof(int32_t) * 1);
    memcpy(r292, r288, sizeof(int32_t) * 400);
    memcpy(r293, r287, sizeof(int32_t) * 400);
    for (long t5421 = 0; t5421 < 12; ++t5421) {
        /* add [add] -> r294 */
        for (long i6422 = 0; i6422 < 1; ++i6422) {
            r294[i6422] = add32(r291[0], r30[0]);
        }
        /* add [add] -> r295 */
        for (long i6423 = 0; i6423 < 400; ++i6423) {
            r295[i6423] = add32(r292[i6423], r293[i6423]);
        }
        /* shra [shift_right_arithmetic] -> r296 */
        for (long i6424 = 0; i6424 < 400; ++i6424) {
            r296[i6424] = asr32(r295[i6424], 1);
        }
        /* broadcast [broadcast_in_dim] -> r297 */
        for (long i6425 = 0; i6425 < 400; ++i6425) {
            long t6427 = i6425;
            long c64260 = t6427 / 80; t6427 %= 80;
            long c64261 = t6427 / 80; t6427 %= 80;
            long c64262 = t6427 / 1; t6427 %= 1;
            long c64263 = t6427;
            r297[i6425] = r296[c64260 * 80 + c64262 * 1];
        }
        /* sub [sub] -> r298 */
        for (long i6428 = 0; i6428 < 6400; ++i6428) {
            long t6430 = i6428;
            long c64290 = t6430 / 1280; t6430 %= 1280;
            long c64291 = t6430 / 1280; t6430 %= 1280;
            long c64292 = t6430 / 16; t6430 %= 16;
            long c64293 = t6430;
            r298[i6428] = sub32(r289[c64290 * 1280 + c64292 * 16 + c64293 * 1], r297[c64290 * 80 + c64292 * 1]);
        }
        /* max [max] -> r299 */
        for (long i6431 = 0; i6431 < 6400; ++i6431) {
            r299[i6431] = max32(r298[i6431], r40[0]);
        }
        /* reduce_sum [reduce_sum] -> r300 */
        for (long i6432 = 0; i6432 < 400; ++i6432) {
            r300[i6432] = 0;
        }
        for (long i6433 = 0; i6433 < 6400; ++i6433) {
            long t6435 = i6433;
            long c64340 = t6435 / 1280; t6435 %= 1280;
            long c64341 = t6435 / 1280; t6435 %= 1280;
            long c64342 = t6435 / 16; t6435 %= 16;
            long c64343 = t6435;
            r300[c64340 * 80 + c64341 * 80 + c64342 * 1] = add32(r300[c64340 * 80 + c64341 * 80 + c64342 * 1], r299[i6433]);
        }
        /* neg [neg] -> r301 */
        for (long i6436 = 0; i6436 < 6400; ++i6436) {
            r301[i6436] = neg32(r289[i6436]);
        }
        /* broadcast [broadcast_in_dim] -> r302 */
        for (long i6437 = 0; i6437 < 400; ++i6437) {
            long t6439 = i6437;
            long c64380 = t6439 / 80; t6439 %= 80;
            long c64381 = t6439 / 80; t6439 %= 80;
            long c64382 = t6439 / 1; t6439 %= 1;
            long c64383 = t6439;
            r302[i6437] = r296[c64380 * 80 + c64382 * 1];
        }
        /* sub [sub] -> r303 */
        for (long i6440 = 0; i6440 < 6400; ++i6440) {
            long t6442 = i6440;
            long c64410 = t6442 / 1280; t6442 %= 1280;
            long c64411 = t6442 / 1280; t6442 %= 1280;
            long c64412 = t6442 / 16; t6442 %= 16;
            long c64413 = t6442;
            r303[i6440] = sub32(r301[c64410 * 1280 + c64412 * 16 + c64413 * 1], r302[c64410 * 80 + c64412 * 1]);
        }
        /* max [max] -> r304 */
        for (long i6443 = 0; i6443 < 6400; ++i6443) {
            r304[i6443] = max32(r303[i6443], r40[0]);
        }
        /* reduce_sum [reduce_sum] -> r305 */
        for (long i6444 = 0; i6444 < 400; ++i6444) {
            r305[i6444] = 0;
        }
        for (long i6445 = 0; i6445 < 6400; ++i6445) {
            long t6447 = i6445;
            long c64460 = t6447 / 1280; t6447 %= 1280;
            long c64461 = t6447 / 1280; t6447 %= 1280;
            long c64462 = t6447 / 16; t6447 %= 16;
            long c64463 = t6447;
            r305[c64460 * 80 + c64461 * 80 + c64462 * 1] = add32(r305[c64460 * 80 + c64461 * 80 + c64462 * 1], r304[i6445]);
        }
        /* add [add] -> r306 */
        for (long i6448 = 0; i6448 < 400; ++i6448) {
            r306[i6448] = add32(r300[i6448], r305[i6448]);
        }
        /* gt [gt] -> r307 */
        for (long i6449 = 0; i6449 < 400; ++i6449) {
            r307[i6449] = r306[i6449] > r290[0] ? 1 : 0;
        }
        /* select_n [select_n] -> r308 */
        for (long i6450 = 0; i6450 < 400; ++i6450) {
            r308[i6450] = r307[i6450] == 0 ? r292[i6450] : (r296[i6450]);
        }
        /* select_n [select_n] -> r309 */
        for (long i6451 = 0; i6451 < 400; ++i6451) {
            r309[i6451] = r307[i6451] == 0 ? r296[i6451] : (r293[i6451]);
        }
        memcpy(r291, r294, sizeof(int32_t) * 1);
        memcpy(r292, r308, sizeof(int32_t) * 400);
        memcpy(r293, r309, sizeof(int32_t) * 400);
    }
    memcpy(r310, r291, sizeof(int32_t) * 1);
    memcpy(r311, r292, sizeof(int32_t) * 400);
    memcpy(r312, r293, sizeof(int32_t) * 400);
    /* sub [sub] -> r313 */
    for (long i6452 = 0; i6452 < 400; ++i6452) {
        r313[i6452] = sub32(r285[i6452], r312[i6452]);
    }
    /* transpose [transpose] -> r314 */
    for (long i6453 = 0; i6453 < 400; ++i6453) {
        long t6455 = i6453;
        long c64540 = t6455 / 400; t6455 %= 400;
        long c64541 = t6455 / 80; t6455 %= 80;
        long c64542 = t6455;
        r314[i6453] = r313[c64540 * 80 + c64541 * 80 + c64542 * 1];
    }
    /* broadcast [broadcast_in_dim] -> r315 */
    for (long i6456 = 0; i6456 < 1; ++i6456) {
        long t6458 = i6456;
        long c64570 = t6458 / 1; t6458 %= 1;
        long c64571 = t6458;
        r315[i6456] = r231[0];
    }
    /* max [max] -> r316 */
    for (long i6459 = 0; i6459 < 400; ++i6459) {
        r316[i6459] = max32(r314[i6459], r40[0]);
    }
    /* iota [iota] -> r317 */
    for (long i6460 = 0; i6460 < 400; ++i6460) {
        long t6462 = i6460;
        long c64610 = t6462 / 400; t6462 %= 400;
        long c64611 = t6462 / 80; t6462 %= 80;
        long c64612 = t6462;
        r317[i6460] = (int32_t)c64612;
    }
    /* broadcast [broadcast_in_dim] -> r318 */
    for (long i6463 = 0; i6463 < 1; ++i6463) {
        long t6465 = i6463;
        long c64640 = t6465 / 1; t6465 %= 1;
        long c64641 = t6465 / 1; t6465 %= 1;
        long c64642 = t6465;
        r318[i6463] = r315[0];
    }
    /* lt [lt] -> r319 */
    for (long i6466 = 0; i6466 < 400; ++i6466) {
        long t6468 = i6466;
        long c64670 = t6468 / 400; t6468 %= 400;
        long c64671 = t6468 / 80; t6468 %= 80;
        long c64672 = t6468;
        r319[i6466] = r317[c64671 * 80 + c64672 * 1] < r318[0] ? 1 : 0;
    }
    /* convert [convert_element_type] -> r320 */
    for (long i6469 = 0; i6469 < 1; ++i6469) {
        r320[i6469] = (int32_t)r40[0];
    }
    /* broadcast [broadcast_in_dim] -> r321 */
    for (long i6470 = 0; i6470 < 400; ++i6470) {
        long t6472 = i6470;
        long c64710 = t6472 / 400; t6472 %= 400;
        long c64711 = t6472 / 80; t6472 %= 80;
        long c64712 = t6472;
        r321[i6470] = r320[0];
    }
    /* select_n [select_n] -> r322 */
    for (long i6473 = 0; i6473 < 400; ++i6473) {
        r322[i6473] = r319[i6473] == 0 ? r321[i6473] : (r316[i6473]);
    }
    /* reduce_sum [reduce_sum] -> r323 */
    for (long i6474 = 0; i6474 < 5; ++i6474) {
        r323[i6474] = 0;
    }
    for (long i6475 = 0; i6475 < 400; ++i6475) {
        long t6477 = i6475;
        long c64760 = t6477 / 400; t6477 %= 400;
        long c64761 = t6477 / 80; t6477 %= 80;
        long c64762 = t6477;
        r323[c64760 * 5 + c64761 * 1] = add32(r323[c64760 * 5 + c64761 * 1], r322[i6475]);
    }
    /* shl [shift_left] -> r324 */
    for (long i6478 = 0; i6478 < 5; ++i6478) {
        r324[i6478] = shl32(r323[i6478], 1);
    }
    /* lt [lt] -> r325 */
    for (long i6479 = 0; i6479 < 1; ++i6479) {
        r325[i6479] = r231[i6479] < r40[0] ? 1 : 0;
    }
    /* add [add] -> r326 */
    for (long i6480 = 0; i6480 < 1; ++i6480) {
        r326[i6480] = add32(r231[i6480], r243[0]);
    }
    /* select_n [select_n] -> r327 */
    for (long i6481 = 0; i6481 < 1; ++i6481) {
        r327[i6481] = r325[i6481] == 0 ? r231[i6481] : (r326[i6481]);
    }
    /* broadcast [broadcast_in_dim] -> r328 */
    for (long i6482 = 0; i6482 < 1; ++i6482) {
        long t6484 = i6482;
        long c64830 = t6484 / 1; t6484 %= 1;
        long c64831 = t6484;
        r328[i6482] = r327[0];
    }
    /* gather [gather] -> r329 */
    for (long i6485 = 0; i6485 < 15; ++i6485) {
        long t6487 = i6485;
        long c64860 = t6487 / 15; t6487 %= 15;
        long c64861 = t6487;
        long row6488 = c64860 * 1;
        long s6489 = clamp_start((long)r328[row6488 + 0], 95, 15);
        r329[i6485] = r232[c64860 * 95 + (s6489 + c64861) * 1];
    }
    /* add [add] -> r330 */
    for (long i6490 = 0; i6490 < 1; ++i6490) {
        r330[i6490] = add32(r7[i6490], r231[i6490]);
    }
    /* and [and] -> r331 */
    for (long i6491 = 0; i6491 < 1; ++i6491) {
        r331[i6491] = r7[i6491] & r30[0];
    }
    /* slice [slice] -> r332 */
    for (long i6492 = 0; i6492 < 85; ++i6492) {
        long t6494 = i6492;
        long c64930 = t6494 / 85; t6494 %= 85;
        long c64931 = t6494;
        r332[i6492] = r232[(0 + c64930 * 1) * 95 + (10 + c64931 * 1) * 1];
    }
    /* shl [shift_left] -> r333 */
    for (long i6495 = 0; i6495 < 85; ++i6495) {
        r333[i6495] = shl32(r332[i6495], 1);
    }
    /* convert [convert_element_type] -> r334 */
    for (long i6496 = 0; i6496 < 1; ++i6496) {
        r334[i6496] = (int32_t)r40[0];
    }
    /* pad [pad] -> r335 */
    for (long i6497 = 0; i6497 < 86; ++i6497) {
        r335[i6497] = r334[0];
    }
    for (long i6498 = 0; i6498 < 85; ++i6498) {
        long t6500 = i6498;
        long c64990 = t6500 / 85; t6500 %= 85;
        long c64991 = t6500;
        long d6501 = 0 + c64990 * 1;
        long d6502 = 0 + c64991 * 1;
        if (d6501 >= 0 && d6501 < 1 && d6502 >= 0 && d6502 < 86) r335[d6501 * 86 + d6502 * 1] = r333[i6498];
    }
    /* iota [iota] -> r336 */
    for (long i6503 = 0; i6503 < 40; ++i6503) {
        long t6505 = i6503;
        long c65040 = t6505;
        r336[i6503] = (int32_t)c65040;
    }
    /* shl [shift_left] -> r337 */
    for (long i6506 = 0; i6506 < 40; ++i6506) {
        r337[i6506] = shl32(r336[i6506], 1);
    }
    /* broadcast [broadcast_in_dim] -> r338 */
    for (long i6507 = 0; i6507 < 40; ++i6507) {
        long t6509 = i6507;
        long c65080 = t6509 / 1; t6509 %= 1;
        long c65081 = t6509;
        r338[i6507] = r337[c65080 * 1];
    }
    /* iota [iota] -> r339 */
    for (long i6510 = 0; i6510 < 6; ++i6510) {
        long t6512 = i6510;
        long c65110 = t6512;
        r339[i6510] = (int32_t)c65110;
    }
    /* broadcast [broadcast_in_dim] -> r340 */
    for (long i6513 = 0; i6513 < 6; ++i6513) {
        long t6515 = i6513;
        long c65140 = t6515 / 6; t6515 %= 6;
        long c65141 = t6515;
        r340[i6513] = r339[c65141 * 1];
    }
    /* add [add] -> r341 */
    for (long i6516 = 0; i6516 < 240; ++i6516) {
        long t6518 = i6516;
        long c65170 = t6518 / 6; t6518 %= 6;
        long c65171 = t6518;
        r341[i6516] = add32(r338[c65170 * 1], r340[c65171 * 1]);
    }
    /* broadcast [broadcast_in_dim] -> r342 */
    for (long i6519 = 0; i6519 < 240; ++i6519) {
        long t6521 = i6519;
        long c65200 = t6521 / 240; t6521 %= 240;
        long c65201 = t6521 / 6; t6521 %= 6;
        long c65202 = t6521;
        r342[i6519] = r341[c65201 * 6 + c65202 * 1];
    }
    /* broadcast [broadcast_in_dim] -> r343 */
    for (long i6522 = 0; i6522 < 1; ++i6522) {
        long t6524 = i6522;
        long c65230 = t6524 / 1; t6524 %= 1;
        long c65231 = t6524 / 1; t6524 %= 1;
        long c65232 = t6524;
        r343[i6522] = r331[0];
    }
    /* add [add] -> r344 */
    for (long i6525 = 0; i6525 < 240; ++i6525) {
        long t6527 = i6525;
        long c65260 = t6527 / 240; t6527 %= 240;
        long c65261 = t6527 / 6; t6527 %= 6;
        long c65262 = t6527;
        r344[i6525] = add32(r343[0], r342[c65261 * 6 + c65262 * 1]);
    }
    /* lt [lt] -> r345 */
    for (long i6528 = 0; i6528 < 240; ++i6528) {
        r345[i6528] = r344[i6528] < r40[0] ? 1 : 0;
    }
    /* add [add] -> r347 */
    for (long i6529 = 0; i6529 < 240; ++i6529) {
        r347[i6529] = add32(r344[i6529], r346[0]);
    }
    /* select_n [select_n] -> r348 */
    for (long i6530 = 0; i6530 < 240; ++i6530) {
        r348[i6530] = r345[i6530] == 0 ? r344[i6530] : (r347[i6530]);
    }
    /* broadcast [broadcast_in_dim] -> r349 */
    for (long i6531 = 0; i6531 < 240; ++i6531) {
        long t6533 = i6531;
        long c65320 = t6533 / 240; t6533 %= 240;
        long c65321 = t6533 / 6; t6533 %= 6;
        long c65322 = t6533 / 1; t6533 %= 1;
        long c65323 = t6533;
        r349[i6531] = r348[c65321 * 6 + c65322 * 1];
    }
    /* gather [gather] -> r350 */
    for (long i6534 = 0; i6534 < 240; ++i6534) {
        long t6536 = i6534;
        long c65350 = t6536 / 240; t6536 %= 240;
        long c65351 = t6536 / 6; t6536 %= 6;
        long c65352 = t6536;
        long row6537 = c65350 * 240 + c65351 * 6 + c65352 * 1;
        long s6538 = clamp_start((long)r349[row6537 + 0], 86, 1);
        r350[i6534] = r335[c65350 * 86 + s6538 * 1];
    }
    /* mov [device_put] -> r351 */
    memcpy(r351, r19, sizeof(int32_t) * 6);
    /* broadcast [broadcast_in_dim] -> r352 */
    for (long i6539 = 0; i6539 < 6; ++i6539) {
        long t6541 = i6539;
        long c65400 = t6541 / 6; t6541 %= 6;
        long c65401 = t6541 / 6; t6541 %= 6;
        long c65402 = t6541;
        r352[i6539] = r351[c65402 * 1];
    }
    /* add [add] -> r353 */
    for (long i6542 = 0; i6542 < 240; ++i6542) {
        long t6544 = i6542;
        long c65430 = t6544 / 240; t6544 %= 240;
        long c65431 = t6544 / 6; t6544 %= 6;
        long c65432 = t6544;
        r353[i6542] = add32(r352[c65432 * 1], r350[c65431 * 6 + c65432 * 1]);
    }
    /* convert [convert_element_type] -> r354 */
    for (long i6545 = 0; i6545 < 1; ++i6545) {
        r354[i6545] = (int32_t)r49[0];
    }
    /* max [max] -> r355 */
    for (long i6546 = 0; i6546 < 240; ++i6546) {
        r355[i6546] = max32(r354[0], r353[i6546]);
    }
    /* convert [convert_element_type] -> r356 */
    for (long i6547 = 0; i6547 < 1; ++i6547) {
        r356[i6547] = (int32_t)r50[0];
    }
    /* min [min] -> r357 */
    for (long i6548 = 0; i6548 < 240; ++i6548) {
        r357[i6548] = min32(r356[0], r355[i6548]);
    }
    /* broadcast [broadcast_in_dim] -> r358 */
    for (long i6549 = 0; i6549 < 6; ++i6549) {
        long t6551 = i6549;
        long c65500 = t6551 / 6; t6551 %= 6;
        long c65501 = t6551 / 6; t6551 %= 6;
        long c65502 = t6551;
        r358[i6549] = r351[c65502 * 1];
    }
    /* sub [sub] -> r359 */
    for (long i6552 = 0; i6552 < 240; ++i6552) {
        long t6554 = i6552;
        long c65530 = t6554 / 240; t6554 %= 240;
        long c65531 = t6554 / 6; t6554 %= 6;
        long c65532 = t6554;
        r359[i6552] = sub32(r358[c65532 * 1], r350[c65531 * 6 + c65532 * 1]);
    }
    /* convert [convert_element_type] -> r360 */
    for (long i6555 = 0; i6555 < 1; ++i6555) {
        r360[i6555] = (int32_t)r49[0];
    }
    /* max [max] -> r361 */
    for (long i6556 = 0; i6556 < 240; ++i6556) {
        r361[i6556] = max32(r360[0], r359[i6556]);
    }
    /* convert [convert_element_type] -> r362 */
    for (long i6557 = 0; i6557 < 1; ++i6557) {
        r362[i6557] = (int32_t)r50[0];
    }
    /* min [min] -> r363 */
    for (long i6558 = 0; i6558 < 240; ++i6558) {
        r363[i6558] = min32(r362[0], r361[i6558]);
    }
    /* abs [abs] -> r364 */
    for (long i6559 = 0; i6559 < 240; ++i6559) {
        r364[i6559] = abs32(r357[i6559]);
    }
    /* reduce_max [reduce_max] -> r365 */
    for (long i6560 = 0; i6560 < 40; ++i6560) {
        r365[i6560] = (-2147483647 - 1);
    }
    for (long i6561 = 0; i6561 < 240; ++i6561) {
        long t6563 = i6561;
        long c65620 = t6563 / 240; t6563 %= 240;
        long c65621 = t6563 / 6; t6563 %= 6;
        long c65622 = t6563;
        r365[c65620 * 40 + c65621 * 1] = max32(r365[c65620 * 40 + c65621 * 1], r364[i6561]);
    }
    /* sub [sub] -> r366 */
    for (long i6564 = 0; i6564 < 40; ++i6564) {
        r366[i6564] = sub32(r365[i6564], r62[0]);
    }
    /* loop [scan] -> r388 */
    memcpy(r367, r357, sizeof(int32_t) * 240);
    memcpy(r368, r62, sizeof(int32_t) * 1);
    memcpy(r369, r40, sizeof(int32_t) * 1);
    memcpy(r370, r366, sizeof(int32_t) * 40);
    memcpy(r371, r365, sizeof(int32_t) * 40);
    for (long t6565 = 0; t6565 < 12; ++t6565) {
        /* add [add] -> r372 */
        for (long i7566 = 0; i7566 < 1; ++i7566) {
            r372[i7566] = add32(r369[0], r30[0]);
        }
        /* add [add] -> r373 */
        for (long i7567 = 0; i7567 < 40; ++i7567) {
            r373[i7567] = add32(r370[i7567], r371[i7567]);
        }
        /* shra [shift_right_arithmetic] -> r374 */
        for (long i7568 = 0; i7568 < 40; ++i7568) {
            r374[i7568] = asr32(r373[i7568], 1);
        }
        /* broadcast [broadcast_in_dim] -> r375 */
        for (long i7569 = 0; i7569 < 40; ++i7569) {
            long t7571 = i7569;
            long c75700 = t7571 / 40; t7571 %= 40;
            long c75701 = t7571 / 1; t7571 %= 1;
            long c75702 = t7571;
            r375[i7569] = r374[c75701 * 1];
        }
        /* sub [sub] -> r376 */
        for (long i7572 = 0; i7572 < 240; ++i7572) {
            long t7574 = i7572;
            long c75730 = t7574 / 240; t7574 %= 240;
            long c75731 = t7574 / 6; t7574 %= 6;
            long c75732 = t7574;
            r376[i7572] = sub32(r367[c75731 * 6 + c75732 * 1], r375[c75731 * 1]);
        }
        /* max [max] -> r377 */
        for (long i7575 = 0; i7575 < 240; ++i7575) {
            r377[i7575] = max32(r376[i7575], r40[0]);
        }
        /* reduce_sum [reduce_sum] -> r378 */
        for (long i7576 = 0; i7576 < 40; ++i7576) {
            r378[i7576] = 0;
        }
        for (long i7577 = 0; i7577 < 240; ++i7577) {
            long t7579 = i7577;
            long c75780 = t7579 / 240; t7579 %= 240;
            long c75781 = t7579 / 6; t7579 %= 6;
            long c75782 = t7579;
            r378[c75780 * 40 + c75781 * 1] = add32(r378[c75780 * 40 + c75781 * 1], r377[i7577]);
        }
        /* neg [neg] -> r379 */
        for (long i7580 = 0; i7580 < 240; ++i7580) {
            r379[i7580] = neg32(r367[i7580]);
        }
        /* broadcast [broadcast_in_dim] -> r380 */
        for (long i7581 = 0; i7581 < 40; ++i7581) {
            long t7583 = i7581;
            long c75820 = t7583 / 40; t7583 %= 40;
            long c75821 = t7583 / 1; t7583 %= 1;
            long c75822 = t7583;
            r380[i7581] = r374[c75821 * 1];
        }
        /* sub [sub] -> r381 */
        for (long i7584 = 0; i7584 < 240; ++i7584) {
            long t7586 = i7584;
            long c75850 = t7586 / 240; t7586 %= 240;
            long c75851 = t7586 / 6; t7586 %= 6;
            long c75852 = t7586;
            r381[i7584] = sub32(r379[c75851 * 6 + c75852 * 1], r380[c75851 * 1]);
        }
        /* max [max] -> r382 */
        for (long i7587 = 0; i7587 < 240; ++i7587) {
            r382[i7587] = max32(r381[i7587], r40[0]);
        }
        /* reduce_sum [reduce_sum] -> r383 */
        for (long i7588 = 0; i7588 < 40; ++i7588) {
            r383[i7588] = 0;
        }
        for (long i7589 = 0; i7589 < 240; ++i7589) {
            long t7591 = i7589;
            long c75900 = t7591 / 240; t7591 %= 240;
            long c75901 = t7591 / 6; t7591 %= 6;
            long c75902 = t7591;
            r383[c75900 * 40 + c75901 * 1] = add32(r383[c75900 * 40 + c75901 * 1], r382[i7589]);
        }
        /* add [add] -> r384 */
        for (long i7592 = 0; i7592 < 40; ++i7592) {
            r384[i7592] = add32(r378[i7592], r383[i7592]);
        }
        /* gt [gt] -> r385 */
        for (long i7593 = 0; i7593 < 40; ++i7593) {
            r385[i7593] = r384[i7593] > r368[0] ? 1 : 0;
        }
        /* select_n [select_n] -> r386 */
        for (long i7594 = 0; i7594 < 40; ++i7594) {
            r386[i7594] = r385[i7594] == 0 ? r370[i7594] : (r374[i7594]);
        }
        /* select_n [select_n] -> r387 */
        for (long i7595 = 0; i7595 < 40; ++i7595) {
            r387[i7595] = r385[i7595] == 0 ? r374[i7595] : (r371[i7595]);
        }
        memcpy(r369, r372, sizeof(int32_t) * 1);
        memcpy(r370, r386, sizeof(int32_t) * 40);
        memcpy(r371, r387, sizeof(int32_t) * 40);
    }
    memcpy(r388, r369, sizeof(int32_t) * 1);
    memcpy(r389, r370, sizeof(int32_t) * 40);
    memcpy(r390, r371, sizeof(int32_t) * 40);
    /* abs [abs] -> r391 */
    for (long i7596 = 0; i7596 < 240; ++i7596) {
        r391[i7596] = abs32(r363[i7596]);
    }
    /* reduce_max [reduce_max] -> r392 */
    for (long i7597 = 0; i7597 < 40; ++i7597) {
        r392[i7597] = (-2147483647 - 1);
    }
    for (long i7598 = 0; i7598 < 240; ++i7598) {
        long t7600 = i7598;
        long c75990 = t7600 / 240; t7600 %= 240;
        long c75991 = t7600 / 6; t7600 %= 6;
        long c75992 = t7600;
        r392[c75990 * 40 + c75991 * 1] = max32(r392[c75990 * 40 + c75991 * 1], r391[i7598]);
    }
    /* sub [sub] -> r393 */
    for (long i7601 = 0; i7601 < 40; ++i7601) {
        r393[i7601] = sub32(r392[i7601], r62[0]);
    }
    /* loop [scan] -> r415 */
    memcpy(r394, r363, sizeof(int32_t) * 240);
    memcpy(r395, r62, sizeof(int32_t) * 1);
    memcpy(r396, r40, sizeof(int32_t) * 1);
    memcpy(r397, r393, sizeof(int32_t) * 40);
    memcpy(r398, r392, sizeof(int32_t) * 40);
    for (long t7602 = 0; t7602 < 12; ++t7602) {
        /* add [add] -> r399 */
        for (long i8603 = 0; i8603 < 1; ++i8603) {
            r399[i8603] = add32(r396[0], r30[0]);
        }
        /* add [add] -> r400 */
        for (long i8604 = 0; i8604 < 40; ++i8604) {
            r400[i8604] = add32(r397[i8604], r398[i8604]);
        }
        /* shra [shift_right_arithmetic] -> r401 */
        for (long i8605 = 0; i8605 < 40; ++i8605) {
            r401[i8605] = asr32(r400[i8605], 1);
        }
        /* broadcast [broadcast_in_dim] -> r402 */
        for (long i8606 = 0; i8606 < 40; ++i8606) {
            long t8608 = i8606;
            long c86070 = t8608 / 40; t8608 %= 40;
            long c86071 = t8608 / 1; t8608 %= 1;
            long c86072 = t8608;
            r402[i8606] = r401[c86071 * 1];
        }
        /* sub [sub] -> r403 */
        for (long i8609 = 0; i8609 < 240; ++i8609) {
            long t8611 = i8609;
            long c86100 = t8611 / 240; t8611 %= 240;
            long c86101 = t8611 / 6; t8611 %= 6;
            long c86102 = t8611;
            r403[i8609] = sub32(r394[c86101 * 6 + c86102 * 1], r402[c86101 * 1]);
        }
        /* max [max] -> r404 */
        for (long i8612 = 0; i8612 < 240; ++i8612) {
            r404[i8612] = max32(r403[i8612], r40[0]);
        }
        /* reduce_sum [reduce_sum] -> r405 */
        for (long i8613 = 0; i8613 < 40; ++i8613) {
            r405[i8613] = 0;
        }
        for (long i8614 = 0; i8614 < 240; ++i8614) {
            long t8616 = i8614;
            long c86150 = t8616 / 240; t8616 %= 240;
            long c86151 = t8616 / 6; t8616 %= 6;
            long c86152 = t8616;
            r405[c86150 * 40 + c86151 * 1] = add32(r405[c86150 * 40 + c86151 * 1], r404[i8614]);
        }
        /* neg [neg] -> r406 */
        for (long i8617 = 0; i8617 < 240; ++i8617) {
            r406[i8617] = neg32(r394[i8617]);
        }
        /* broadcast [broadcast_in_dim] -> r407 */
        for (long i8618 = 0; i8618 < 40; ++i8618) {
            long t8620 = i8618;
            long c86190 = t8620 / 40; t8620 %= 40;
            long c86191 = t8620 / 1; t8620 %= 1;
            long c86192 = t8620;
            r407[i8618] = r401[c86191 * 1];
        }
        /* sub [sub] -> r408 */
        for (long i8621 = 0; i8621 < 240; ++i8621) {
            long t8623 = i8621;
            long c86220 = t8623 / 240; t8623 %= 240;
            long c86221 = t8623 / 6; t8623 %= 6;
            long c86222 = t8623;
            r408[i8621] = sub32(r406[c86221 * 6 + c86222 * 1], r407[c86221 * 1]);
        }
        /* max [max] -> r409 */
        for (long i8624 = 0; i8624 < 240; ++i8624) {
            r409[i8624] = max32(r408[i8624], r40[0]);
        }
        /* reduce_sum [reduce_sum] -> r410 */
        for (long i8625 = 0; i8625 < 40; ++i8625) {
            r410[i8625] = 0;
        }
        for (long i8626 = 0; i8626 < 240; ++i8626) {
            long t8628 = i8626;
            long c86270 = t8628 / 240; t8628 %= 240;
            long c86271 = t8628 / 6; t8628 %= 6;
            long c86272 = t8628;
            r410[c86270 * 40 + c86271 * 1] = add32(r410[c86270 * 40 + c86271 * 1], r409[i8626]);
        }
        /* add [add] -> r411 */
        for (long i8629 = 0; i8629 < 40; ++i8629) {
            r411[i8629] = add32(r405[i8629], r410[i8629]);
        }
        /* gt [gt] -> r412 */
        for (long i8630 = 0; i8630 < 40; ++i8630) {
            r412[i8630] = r411[i8630] > r395[0] ? 1 : 0;
        }
        /* select_n [select_n] -> r413 */
        for (long i8631 = 0; i8631 < 40; ++i8631) {
            r413[i8631] = r412[i8631] == 0 ? r397[i8631] : (r401[i8631]);
        }
        /* select_n [select_n] -> r414 */
        for (long i8632 = 0; i8632 < 40; ++i8632) {
            r414[i8632] = r412[i8632] == 0 ? r401[i8632] : (r398[i8632]);
        }
        memcpy(r396, r399, sizeof(int32_t) * 1);
        memcpy(r397, r413, sizeof(int32_t) * 40);
        memcpy(r398, r414, sizeof(int32_t) * 40);
    }
    memcpy(r415, r396, sizeof(int32_t) * 1);
    memcpy(r416, r397, sizeof(int32_t) * 40);
    memcpy(r417, r398, sizeof(int32_t) * 40);
    /* sub [sub] -> r418 */
    for (long i8633 = 0; i8633 < 40; ++i8633) {
        r418[i8633] = sub32(r390[i8633], r417[i8633]);
    }
    /* shra [shift_right_arithmetic] -> r419 */
    for (long i8634 = 0; i8634 < 40; ++i8634) {
        r419[i8634] = asr32(r418[i8634], 1);
    }
    /* convert [convert_element_type] -> r420 */
    for (long i8635 = 0; i8635 < 1; ++i8635) {
        r420[i8635] = (int32_t)r222[0];
    }
    /* max [max] -> r421 */
    for (long i8636 = 0; i8636 < 40; ++i8636) {
        r421[i8636] = max32(r420[0], r419[i8636]);
    }
    /* convert [convert_element_type] -> r422 */
    for (long i8637 = 0; i8637 < 1; ++i8637) {
        r422[i8637] = (int32_t)r223[0];
    }
    /* min [min] -> r423 */
    for (long i8638 = 0; i8638 < 40; ++i8638) {
        r423[i8638] = min32(r422[0], r421[i8638]);
    }
    /* sub [sub] -> r424 */
    for (long i8639 = 0; i8639 < 1; ++i8639) {
        r424[i8639] = sub32(r231[i8639], r331[i8639]);
    }
    /* add [add] -> r425 */
    for (long i8640 = 0; i8640 < 1; ++i8640) {
        r425[i8640] = add32(r424[i8640], r30[0]);
    }
    /* max [max] -> r426 */
    for (long i8641 = 0; i8641 < 1; ++i8641) {
        r426[i8641] = max32(r425[i8641], r40[0]);
    }
    /* shra [shift_right_arithmetic] -> r427 */
    for (long i8642 = 0; i8642 < 1; ++i8642) {
        r427[i8642] = asr32(r426[i8642], 1);
    }
    /* concat [concatenate] -> r428 */
    for (long i8643 = 0; i8643 < 15; ++i8643) {
        long t8645 = i8643;
        long c86440 = t8645 / 15; t8645 %= 15;
        long c86441 = t8645;
        r428[c86440 * 55 + (c86441 + 0) * 1] = r2[i8643];
    }
    for (long i8646 = 0; i8646 < 40; ++i8646) {
        long t8648 = i8646;
        long c86470 = t8648 / 40; t8648 %= 40;
        long c86471 = t8648;
        r428[c86470 * 55 + (c86471 + 15) * 1] = r423[i8646];
    }
    /* shl [shift_left] -> r429 */
    for (long i8649 = 0; i8649 < 55; ++i8649) {
        r429[i8649] = shl32(r428[i8649], 1);
    }
    /* mov [device_put] -> r430 */
    memcpy(r430, r18, sizeof(int32_t) * 80);
    /* rev [rev] -> r431 */
    for (long i8650 = 0; i8650 < 80; ++i8650) {
        long t8652 = i8650;
        long c86510 = t8652 / 16; t8652 %= 16;
        long c86511 = t8652;
        r431[i8650] = r430[c86510 * 16 + (16 - 1 - c86511) * 1];
    }
    /* reshape [reshape] -> r432 */
    memcpy(r432, r431, sizeof(int32_t) * 80);
    /* iota [iota] -> r433 */
    for (long i8653 = 0; i8653 < 40; ++i8653) {
        long t8655 = i8653;
        long c86540 = t8655;
        r433[i8653] = (int32_t)c86540;
    }
    /* broadcast [broadcast_in_dim] -> r434 */
    for (long i8656 = 0; i8656 < 40; ++i8656) {
        long t8658 = i8656;
        long c86570 = t8658 / 1; t8658 %= 1;
        long c86571 = t8658;
        r434[i8656] = r433[c86570 * 1];
    }
    /* iota [iota] -> r435 */
    for (long i8659 = 0; i8659 < 16; ++i8659) {
        long t8661 = i8659;
        long c86600 = t8661;
        r435[i8659] = (int32_t)c86600;
    }
    /* broadcast [broadcast_in_dim] -> r436 */
    for (long i8662 = 0; i8662 < 16; ++i8662) {
        long t8664 = i8662;
        long c86630 = t8664 / 16; t8664 %= 16;
        long c86631 = t8664;
        r436[i8662] = r435[c86631 * 1];
    }
    /* add [add] -> r437 */
    for (long i8665 = 0; i8665 < 640; ++i8665) {
        long t8667 = i8665;
        long c86660 = t8667 / 16; t8667 %= 16;
        long c86661 = t8667;
        r437[i8665] = add32(r434[c86660 * 1], r436[c86661 * 1]);
    }
    /* lt [lt] -> r438 */
    for (long i8668 = 0; i8668 < 640; ++i8668) {
        r438[i8668] = r437[i8668] < r40[0] ? 1 : 0;
    }
    /* add [add] -> r440 */
    for (long i8669 = 0; i8669 < 640; ++i8669) {
        r440[i8669] = add32(r437[i8669], r439[0]);
    }
    /* select_n [select_n] -> r441 */
    for (long i8670 = 0; i8670 < 640; ++i8670) {
        r441[i8670] = r438[i8670] == 0 ? r437[i8670] : (r440[i8670]);
    }
    /* broadcast [broadcast_in_dim] -> r442 */
    for (long i8671 = 0; i8671 < 640; ++i8671) {
        long t8673 = i8671;
        long c86720 = t8673 / 16; t8673 %= 16;
        long c86721 = t8673 / 1; t8673 %= 1;
        long c86722 = t8673;
        r442[i8671] = r441[c86720 * 16 + c86721 * 1];
    }
    /* gather [gather] -> r443 */
    for (long i8674 = 0; i8674 < 640; ++i8674) {
        long t8676 = i8674;
        long c86750 = t8676 / 640; t8676 %= 640;
        long c86751 = t8676 / 16; t8676 %= 16;
        long c86752 = t8676;
        long row8677 = c86751 * 16 + c86752 * 1;
        long s8678 = clamp_start((long)r442[row8677 + 0], 55, 1);
        r443[i8674] = r429[c86750 * 55 + s8678 * 1];
    }
    /* broadcast [broadcast_in_dim] -> r444 */
    for (long i8679 = 0; i8679 < 640; ++i8679) {
        long t8681 = i8679;
        long c86800 = t8681 / 640; t8681 %= 640;
        long c86801 = t8681 / 640; t8681 %= 640;
        long c86802 = t8681 / 16; t8681 %= 16;
        long c86803 = t8681;
        r444[i8679] = r443[c86802 * 16 + c86803 * 1];
    }
    /* add [add] -> r445 */
    for (long i8682 = 0; i8682 < 3200; ++i8682) {
        long t8684 = i8682;
        long c86830 = t8684 / 640; t8684 %= 640;
        long c86831 = t8684 / 640; t8684 %= 640;
        long c86832 = t8684 / 16; t8684 %= 16;
        long c86833 = t8684;
        r445[i8682] = add32(r432[c86830 * 16 + c86833 * 1], r444[c86832 * 16 + c86833 * 1]);
    }
    /* convert [convert_element_type] -> r446 */
    for (long i8685 = 0; i8685 < 1; ++i8685) {
        r446[i8685] = (int32_t)r49[0];
    }
    /* max [max] -> r447 */
    for (long i8686 = 0; i8686 < 3200; ++i8686) {
        r447[i8686] = max32(r446[0], r445[i8686]);
    }
    /* convert [convert_element_type] -> r448 */
    for (long i8687 = 0; i8687 < 1; ++i8687) {
        r448[i8687] = (int32_t)r50[0];
    }
    /* min [min] -> r449 */
    for (long i8688 = 0; i8688 < 3200; ++i8688) {
        r449[i8688] = min32(r448[0], r447[i8688]);
    }
    /* sub [sub] -> r450 */
    for (long i8689 = 0; i8689 < 3200; ++i8689) {
        long t8691 = i8689;
        long c86900 = t8691 / 640; t8691 %= 640;
        long c86901 = t8691 / 640; t8691 %= 640;
        long c86902 = t8691 / 16; t8691 %= 16;
        long c86903 = t8691;
        r450[i8689] = sub32(r432[c86900 * 16 + c86903 * 1], r444[c86902 * 16 + c86903 * 1]);
    }
    /* convert [convert_element_type] -> r451 */
    for (long i8692 = 0; i8692 < 1; ++i8692) {
        r451[i8692] = (int32_t)r49[0];
    }
    /* max [max] -> r452 */
    for (long i8693 = 0; i8693 < 3200; ++i8693) {
        r452[i8693] = max32(r451[0], r450[i8693]);
    }
    /* convert [convert_element_type] -> r453 */
    for (long i8694 = 0; i8694 < 1; ++i8694) {
        r453[i8694] = (int32_t)r50[0];
    }
    /* min [min] -> r454 */
    for (long i8695 = 0; i8695 < 3200; ++i8695) {
        r454[i8695] = min32(r453[0], r452[i8695]);
    }
    /* abs [abs] -> r455 */
    for (long i8696 = 0; i8696 < 3200; ++i8696) {
        r455[i8696] = abs32(r449[i8696]);
    }
    /* reduce_max [reduce_max] -> r456 */
    for (long i8697 = 0; i8697 < 200; ++i8697) {
        r456[i8697] = (-2147483647 - 1);
    }
    for (long i8698 = 0; i8698 < 3200; ++i8698) {
        long t8700 = i8698;
        long c86990 = t8700 / 640; t8700 %= 640;
        long c86991 = t8700 / 640; t8700 %= 640;
        long c86992 = t8700 / 16; t8700 %= 16;
        long c86993 = t8700;
        r456[c86990 * 40 + c86991 * 40 + c86992 * 1] = max32(r456[c86990 * 40 + c86991 * 40 + c86992 * 1], r455[i8698]);
    }
    /* sub [sub] -> r457 */
    for (long i8701 = 0; i8701 < 200; ++i8701) {
        r457[i8701] = sub32(r456[i8701], r62[0]);
    }
    /* loop [scan] -> r479 */
    memcpy(r458, r449, sizeof(int32_t) * 3200);
    memcpy(r459, r62, sizeof(int32_t) * 1);
    memcpy(r460, r40, sizeof(int32_t) * 1);
    memcpy(r461, r457, sizeof(int32_t) * 200);
    memcpy(r462, r456, sizeof(int32_t) * 200);
    for (long t8702 = 0; t8702 < 12; ++t8702) {
        /* add [add] -> r463 */
        for (long i9703 = 0; i9703 < 1; ++i9703) {
            r463[i9703] = add32(r460[0], r30[0]);
        }
        /* add [add] -> r464 */
        for (long i9704 = 0; i9704 < 200; ++i9704) {
            r464[i9704] = add32(r461[i9704], r462[i9704]);
        }
        /* shra [shift_right_arithmetic] -> r465 */
        for (long i9705 = 0; i9705 < 200; ++i9705) {
            r465[i9705] = asr32(r464[i9705], 1);
        }
        /* broadcast [broadcast_in_dim] -> r466 */
        for (long i9706 = 0; i9706 < 200; ++i9706) {
            long t9708 = i9706;
            long c97070 = t9708 / 40; t9708 %= 40;
            long c97071 = t9708 / 40; t9708 %= 40;
            long c97072 = t9708 / 1; t9708 %= 1;
            long c97073 = t9708;
            r466[i9706] = r465[c97070 * 40 + c97072 * 1];
        }
        /* sub [sub] -> r467 */
        for (long i9709 = 0; i9709 < 3200; ++i9709) {
            long t9711 = i9709;
            long c97100 = t9711 / 640; t9711 %= 640;
            long c97101 = t9711 / 640; t9711 %= 640;
            long c97102 = t9711 / 16; t9711 %= 16;
            long c97103 = t9711;
            r467[i9709] = sub32(r458[c97100 * 640 + c97102 * 16 + c97103 * 1], r466[c97100 * 40 + c97102 * 1]);
        }
        /* max [max] -> r468 */
        for (long i9712 = 0; i9712 < 3200; ++i9712) {
            r468[i9712] = max32(r467[i9712], r40[0]);
        }
        /* reduce_sum [reduce_sum] -> r469 */
        for (long i9713 = 0; i9713 < 200; ++i9713) {
            r469[i9713] = 0;
        }
        for (long i9714 = 0; i9714 < 3200; ++i9714) {
            long t9716 = i9714;
            long c97150 = t9716 / 640; t9716 %= 640;
            long c97151 = t9716 / 640; t9716 %= 640;
            long c97152 = t9716 / 16; t9716 %= 16;
            long c97153 = t9716;
            r469[c97150 * 40 + c97151 * 40 + c97152 * 1] = add32(r469[c97150 * 40 + c97151 * 40 + c97152 * 1], r468[i9714]);
        }
        /* neg [neg] -> r470 */
        for (long i9717 = 0; i9717 < 3200; ++i9717) {
            r470[i9717] = neg32(r458[i9717]);
        }
        /* broadcast [broadcast_in_dim] -> r471 */
        for (long i9718 = 0; i9718 < 200; ++i9718) {
            long t9720 = i9718;
            long c97190 = t9720 / 40; t9720 %= 40;
            long c97191 = t9720 / 40; t9720 %= 40;
            long c97192 = t9720 / 1; t9720 %= 1;
            long c97193 = t9720;
            r471[i9718] = r465[c97190 * 40 + c97192 * 1];
        }
        /* sub [sub] -> r472 */
        for (long i9721 = 0; i9721 < 3200; ++i9721) {
            long t9723 = i9721;
            long c97220 = t9723 / 640; t9723 %= 640;
            long c97221 = t9723 / 640; t9723 %= 640;
            long c97222 = t9723 / 16; t9723 %= 16;
            long c97223 = t9723;
            r472[i9721] = sub32(r470[c97220 * 640 + c97222 * 16 + c97223 * 1], r471[c97220 * 40 + c97222 * 1]);
        }
        /* max [max] -> r473 */
        for (long i9724 = 0; i9724 < 3200; ++i9724) {
            r473[i9724] = max32(r472[i9724], r40[0]);
        }
        /* reduce_sum [reduce_sum] -> r474 */
        for (long i9725 = 0; i9725 < 200; ++i9725) {
            r474[i9725] = 0;
        }
        for (long i9726 = 0; i9726 < 3200; ++i9726) {
            long t9728 = i9726;
            long c97270 = t9728 / 640; t9728 %= 640;
            long c97271 = t9728 / 640; t9728 %= 640;
            long c97272 = t9728 / 16; t9728 %= 16;
            long c97273 = t9728;
            r474[c97270 * 40 + c97271 * 40 + c97272 * 1] = add32(r474[c97270 * 40 + c97271 * 40 + c97272 * 1], r473[i9726]);
        }
        /* add [add] -> r475 */
        for (long i9729 = 0; i9729 < 200; ++i9729) {
            r475[i9729] = add32(r469[i9729], r474[i9729]);
        }
        /* gt [gt] -> r476 */
        for (long i9730 = 0; i9730 < 200; ++i9730) {
            r476[i9730] = r475[i9730] > r459[0] ? 1 : 0;
        }
        /* select_n [select_n] -> r477 */
        for (long i9731 = 0; i9731 < 200; ++i9731) {
            r477[i9731] = r476[i9731] == 0 ? r461[i9731] : (r465[i9731]);
        }
        /* select_n [select_n] -> r478 */
        for (long i9732 = 0; i9732 < 200; ++i9732) {
            r478[i9732] = r476[i9732] == 0 ? r465[i9732] : (r462[i9732]);
        }
        memcpy(r460, r463, sizeof(int32_t) * 1);
        memcpy(r461, r477, sizeof(int32_t) * 200);
        memcpy(r462, r478, sizeof(int32_t) * 200);
    }
    memcpy(r479, r460, sizeof(int32_t) * 1);
    memcpy(r480, r461, sizeof(int32_t) * 200);
    memcpy(r481, r462, sizeof(int32_t) * 200);
    /* abs [abs] -> r482 */
    for (long i9733 = 0; i9733 < 3200; ++i9733) {
        r482[i9733] = abs32(r454[i9733]);
    }
    /* reduce_max [reduce_max] -> r483 */
    for (long i9734 = 0; i9734 < 200; ++i9734) {
        r483[i9734] = (-2147483647 - 1);
    }
    for (long i9735 = 0; i9735 < 3200; ++i9735) {
        long t9737 = i9735;
        long c97360 = t9737 / 640; t9737 %= 640;
        long c97361 = t9737 / 640; t9737 %= 640;
        long c97362 = t9737 / 16; t9737 %= 16;
        long c97363 = t9737;
        r483[c97360 * 40 + c97361 * 40 + c97362 * 1] = max32(r483[c97360 * 40 + c97361 * 40 + c97362 * 1], r482[i9735]);
    }
    /* sub [sub] -> r484 */
    for (long i9738 = 0; i9738 < 200; ++i9738) {
        r484[i9738] = sub32(r483[i9738], r62[0]);
    }
    /* loop [scan] -> r506 */
    memcpy(r485, r454, sizeof(int32_t) * 3200);
    memcpy(r486, r62, sizeof(int32_t) * 1);
    memcpy(r487, r40, sizeof(int32_t) * 1);
    memcpy(r488, r484, sizeof(int32_t) * 200);
    memcpy(r489, r483, sizeof(int32_t) * 200);
    for (long t9739 = 0; t9739 < 12; ++t9739) {
        /* add [add] -> r490 */
        for (long i10740 = 0; i10740 < 1; ++i10740) {
            r490[i10740] = add32(r487[0], r30[0]);
        }
        /* add [add] -> r491 */
        for (long i10741 = 0; i10741 < 200; ++i10741) {
            r491[i10741] = add32(r488[i10741], r489[i10741]);
        }
        /* shra [shift_right_arithmetic] -> r492 */
        for (long i10742 = 0; i10742 < 200; ++i10742) {
            r492[i10742] = asr32(r491[i10742], 1);
        }
        /* broadcast [broadcast_in_dim] -> r493 */
        for (long i10743 = 0; i10743 < 200; ++i10743) {
            long t10745 = i10743;
            long c107440 = t10745 / 40; t10745 %= 40;
            long c107441 = t10745 / 40; t10745 %= 40;
            long c107442 = t10745 / 1; t10745 %= 1;
            long c107443 = t10745;
            r493[i10743] = r492[c107440 * 40 + c107442 * 1];
        }
        /* sub [sub] -> r494 */
        for (long i10746 = 0; i10746 < 3200; ++i10746) {
            long t10748 = i10746;
            long c107470 = t10748 / 640; t10748 %= 640;
            long c107471 = t10748 / 640; t10748 %= 640;
            long c107472 = t10748 / 16; t10748 %= 16;
            long c107473 = t10748;
            r494[i10746] = sub32(r485[c107470 * 640 + c107472 * 16 + c107473 * 1], r493[c107470 * 40 + c107472 * 1]);
        }
        /* max [max] -> r495 */
        for (long i10749 = 0; i10749 < 3200; ++i10749) {
            r495[i10749] = max32(r494[i10749], r40[0]);
        }
        /* reduce_sum [reduce_sum] -> r496 */
        for (long i10750 = 0; i10750 < 200; ++i10750) {
            r496[i10750] = 0;
        }
        for (long i10751 = 0; i10751 < 3200; ++i10751) {
            long t10753 = i10751;
            long c107520 = t10753 / 640; t10753 %= 640;
            long c107521 = t10753 / 640; t10753 %= 640;
            long c107522 = t10753 / 16; t10753 %= 16;
            long c107523 = t10753;
            r496[c107520 * 40 + c107521 * 40 + c107522 * 1] = add32(r496[c107520 * 40 + c107521 * 40 + c107522 * 1], r495[i10751]);
        }
        /* neg [neg] -> r497 */
        for (long i10754 = 0; i10754 < 3200; ++i10754) {
            r497[i10754] = neg32(r485[i10754]);
        }
        /* broadcast [broadcast_in_dim] -> r498 */
        for (long i10755 = 0; i10755 < 200; ++i10755) {
            long t10757 = i10755;
            long c107560 = t10757 / 40; t10757 %= 40;
            long c107561 = t10757 / 40; t10757 %= 40;
            long c107562 = t10757 / 1; t10757 %= 1;
            long c107563 = t10757;
            r498[i10755] = r492[c107560 * 40 + c107562 * 1];
        }
        /* sub [sub] -> r499 */
        for (long i10758 = 0; i10758 < 3200; ++i10758) {
            long t10760 = i10758;
            long c107590 = t10760 / 640; t10760 %= 640;
            long c107591 = t10760 / 640; t10760 %= 640;
            long c107592 = t10760 / 16; t10760 %= 16;
            long c107593 = t10760;
            r499[i10758] = sub32(r497[c107590 * 640 + c107592 * 16 + c107593 * 1], r498[c107590 * 40 + c107592 * 1]);
        }
        /* max [max] -> r500 */
        for (long i10761 = 0; i10761 < 3200; ++i10761) {
            r500[i10761] = max32(r499[i10761], r40[0]);
        }
        /* reduce_sum [reduce_sum] -> r501 */
        for (long i10762 = 0; i10762 < 200; ++i10762) {
            r501[i10762] = 0;
        }
        for (long i10763 = 0; i10763 < 3200; ++i10763) {
            long t10765 = i10763;
            long c107640 = t10765 / 640; t10765 %= 640;
            long c107641 = t10765 / 640; t10765 %= 640;
            long c107642 = t10765 / 16; t10765 %= 16;
            long c107643 = t10765;
            r501[c107640 * 40 + c107641 * 40 + c107642 * 1] = add32(r501[c107640 * 40 + c107641 * 40 + c107642 * 1], r500[i10763]);
        }
        /* add [add] -> r502 */
        for (long i10766 = 0; i10766 < 200; ++i10766) {
            r502[i10766] = add32(r496[i10766], r501[i10766]);
        }
        /* gt [gt] -> r503 */
        for (long i10767 = 0; i10767 < 200; ++i10767) {
            r503[i10767] = r502[i10767] > r486[0] ? 1 : 0;
        }
        /* select_n [select_n] -> r504 */
        for (long i10768 = 0; i10768 < 200; ++i10768) {
            r504[i10768] = r503[i10768] == 0 ? r488[i10768] : (r492[i10768]);
        }
        /* select_n [select_n] -> r505 */
        for (long i10769 = 0; i10769 < 200; ++i10769) {
            r505[i10769] = r503[i10769] == 0 ? r492[i10769] : (r489[i10769]);
        }
        memcpy(r487, r490, sizeof(int32_t) * 1);
        memcpy(r488, r504, sizeof(int32_t) * 200);
        memcpy(r489, r505, sizeof(int32_t) * 200);
    }
    memcpy(r506, r487, sizeof(int32_t) * 1);
    memcpy(r507, r488, sizeof(int32_t) * 200);
    memcpy(r508, r489, sizeof(int32_t) * 200);
    /* sub [sub] -> r509 */
    for (long i10770 = 0; i10770 < 200; ++i10770) {
        r509[i10770] = sub32(r481[i10770], r508[i10770]);
    }
    /* transpose [transpose] -> r510 */
    for (long i10771 = 0; i10771 < 200; ++i10771) {
        long t10773 = i10771;
        long c107720 = t10773 / 200; t10773 %= 200;
        long c107721 = t10773 / 40; t10773 %= 40;
        long c107722 = t10773;
        r510[i10771] = r509[c107720 * 40 + c107721 * 40 + c107722 * 1];
    }
    /* broadcast [broadcast_in_dim] -> r511 */
    for (long i10774 = 0; i10774 < 1; ++i10774) {
        long t10776 = i10774;
        long c107750 = t10776 / 1; t10776 %= 1;
        long c107751 = t10776;
        r511[i10774] = r427[0];
    }
    /* max [max] -> r512 */
    for (long i10777 = 0; i10777 < 200; ++i10777) {
        r512[i10777] = max32(r510[i10777], r40[0]);
    }
    /* iota [iota] -> r513 */
    for (long i10778 = 0; i10778 < 200; ++i10778) {
        long t10780 = i10778;
        long c107790 = t10780 / 200; t10780 %= 200;
        long c107791 = t10780 / 40; t10780 %= 40;
        long c107792 = t10780;
        r513[i10778] = (int32_t)c107792;
    }
    /* broadcast [broadcast_in_dim] -> r514 */
    for (long i10781 = 0; i10781 < 1; ++i10781) {
        long t10783 = i10781;
        long c107820 = t10783 / 1; t10783 %= 1;
        long c107821 = t10783 / 1; t10783 %= 1;
        long c107822 = t10783;
        r514[i10781] = r511[0];
    }
    /* lt [lt] -> r515 */
    for (long i10784 = 0; i10784 < 200; ++i10784) {
        long t10786 = i10784;
        long c107850 = t10786 / 200; t10786 %= 200;
        long c107851 = t10786 / 40; t10786 %= 40;
        long c107852 = t10786;
        r515[i10784] = r513[c107851 * 40 + c107852 * 1] < r514[0] ? 1 : 0;
    }
    /* convert [convert_element_type] -> r516 */
    for (long i10787 = 0; i10787 < 1; ++i10787) {
        r516[i10787] = (int32_t)r40[0];
    }
    /* broadcast [broadcast_in_dim] -> r517 */
    for (long i10788 = 0; i10788 < 200; ++i10788) {
        long t10790 = i10788;
        long c107890 = t10790 / 200; t10790 %= 200;
        long c107891 = t10790 / 40; t10790 %= 40;
        long c107892 = t10790;
        r517[i10788] = r516[0];
    }
    /* select_n [select_n] -> r518 */
    for (long i10791 = 0; i10791 < 200; ++i10791) {
        r518[i10791] = r515[i10791] == 0 ? r517[i10791] : (r512[i10791]);
    }
    /* reduce_sum [reduce_sum] -> r519 */
    for (long i10792 = 0; i10792 < 5; ++i10792) {
        r519[i10792] = 0;
    }
    for (long i10793 = 0; i10793 < 200; ++i10793) {
        long t10795 = i10793;
        long c107940 = t10795 / 200; t10795 %= 200;
        long c107941 = t10795 / 40; t10795 %= 40;
        long c107942 = t10795;
        r519[c107940 * 5 + c107941 * 1] = add32(r519[c107940 * 5 + c107941 * 1], r518[i10793]);
    }
    /* shl [shift_left] -> r521 */
    for (long i10796 = 0; i10796 < 5; ++i10796) {
        r521[i10796] = shl32(r519[i10796], 2);
    }
    /* lt [lt] -> r522 */
    for (long i10797 = 0; i10797 < 1; ++i10797) {
        r522[i10797] = r427[i10797] < r40[0] ? 1 : 0;
    }
    /* add [add] -> r523 */
    for (long i10798 = 0; i10798 < 1; ++i10798) {
        r523[i10798] = add32(r427[i10798], r439[0]);
    }
    /* select_n [select_n] -> r524 */
    for (long i10799 = 0; i10799 < 1; ++i10799) {
        r524[i10799] = r522[i10799] == 0 ? r427[i10799] : (r523[i10799]);
    }
    /* broadcast [broadcast_in_dim] -> r525 */
    for (long i10800 = 0; i10800 < 1; ++i10800) {
        long t10802 = i10800;
        long c108010 = t10802 / 1; t10802 %= 1;
        long c108011 = t10802;
        r525[i10800] = r524[0];
    }
    /* gather [gather] -> r526 */
    for (long i10803 = 0; i10803 < 15; ++i10803) {
        long t10805 = i10803;
        long c108040 = t10805 / 15; t10805 %= 15;
        long c108041 = t10805;
        long row10806 = c108040 * 1;
        long s10807 = clamp_start((long)r525[row10806 + 0], 55, 15);
        r526[i10803] = r428[c108040 * 55 + (s10807 + c108041) * 1];
    }
    /* add [add] -> r527 */
    for (long i10808 = 0; i10808 < 1; ++i10808) {
        r527[i10808] = add32(r8[i10808], r427[i10808]);
    }
    /* and [and] -> r528 */
    for (long i10809 = 0; i10809 < 1; ++i10809) {
        r528[i10809] = r8[i10809] & r30[0];
    }
    /* slice [slice] -> r529 */
    for (long i10810 = 0; i10810 < 45; ++i10810) {
        long t10812 = i10810;
        long c108110 = t10812 / 45; t10812 %= 45;
        long c108111 = t10812;
        r529[i10810] = r428[(0 + c108110 * 1) * 55 + (10 + c108111 * 1) * 1];
    }
    /* shl [shift_left] -> r530 */
    for (long i10813 = 0; i10813 < 45; ++i10813) {
        r530[i10813] = shl32(r529[i10813], 1);
    }
    /* convert [convert_element_type] -> r531 */
    for (long i10814 = 0; i10814 < 1; ++i10814) {
        r531[i10814] = (int32_t)r40[0];
    }
    /* pad [pad] -> r532 */
    for (long i10815 = 0; i10815 < 46; ++i10815) {
        r532[i10815] = r531[0];
    }
    for (long i10816 = 0; i10816 < 45; ++i10816) {
        long t10818 = i10816;
        long c108170 = t10818 / 45; t10818 %= 45;
        long c108171 = t10818;
        long d10819 = 0 + c108170 * 1;
        long d10820 = 0 + c108171 * 1;
        if (d10819 >= 0 && d10819 < 1 && d10820 >= 0 && d10820 < 46) r532[d10819 * 46 + d10820 * 1] = r530[i10816];
    }
    /* iota [iota] -> r533 */
    for (long i10821 = 0; i10821 < 20; ++i10821) {
        long t10823 = i10821;
        long c108220 = t10823;
        r533[i10821] = (int32_t)c108220;
    }
    /* shl [shift_left] -> r534 */
    for (long i10824 = 0; i10824 < 20; ++i10824) {
        r534[i10824] = shl32(r533[i10824], 1);
    }
    /* broadcast [broadcast_in_dim] -> r535 */
    for (long i10825 = 0; i10825 < 20; ++i10825) {
        long t10827 = i10825;
        long c108260 = t10827 / 1; t10827 %= 1;
        long c108261 = t10827;
        r535[i10825] = r534[c108260 * 1];
    }
    /* iota [iota] -> r536 */
    for (long i10828 = 0; i10828 < 6; ++i10828) {
        long t10830 = i10828;
        long c108290 = t10830;
        r536[i10828] = (int32_t)c108290;
    }
    /* broadcast [broadcast_in_dim] -> r537 */
    for (long i10831 = 0; i10831 < 6; ++i10831) {
        long t10833 = i10831;
        long c108320 = t10833 / 6; t10833 %= 6;
        long c108321 = t10833;
        r537[i10831] = r536[c108321 * 1];
    }
    /* add [add] -> r538 */
    for (long i10834 = 0; i10834 < 120; ++i10834) {
        long t10836 = i10834;
        long c108350 = t10836 / 6; t10836 %= 6;
        long c108351 = t10836;
        r538[i10834] = add32(r535[c108350 * 1], r537[c108351 * 1]);
    }
    /* broadcast [broadcast_in_dim] -> r539 */
    for (long i10837 = 0; i10837 < 120; ++i10837) {
        long t10839 = i10837;
        long c108380 = t10839 / 120; t10839 %= 120;
        long c108381 = t10839 / 6; t10839 %= 6;
        long c108382 = t10839;
        r539[i10837] = r538[c108381 * 6 + c108382 * 1];
    }
    /* broadcast [broadcast_in_dim] -> r540 */
    for (long i10840 = 0; i10840 < 1; ++i10840) {
        long t10842 = i10840;
        long c108410 = t10842 / 1; t10842 %= 1;
        long c108411 = t10842 / 1; t10842 %= 1;
        long c108412 = t10842;
        r540[i10840] = r528[0];
    }
    /* add [add] -> r541 */
    for (long i10843 = 0; i10843 < 120; ++i10843) {
        long t10845 = i10843;
        long c108440 = t10845 / 120; t10845 %= 120;
        long c108441 = t10845 / 6; t10845 %= 6;
        long c108442 = t10845;
        r541[i10843] = add32(r540[0], r539[c108441 * 6 + c108442 * 1]);
    }
    /* lt [lt] -> r542 */
    for (long i10846 = 0; i10846 < 120; ++i10846) {
        r542[i10846] = r541[i10846] < r40[0] ? 1 : 0;
    }
    /* add [add] -> r544 */
    for (long i10847 = 0; i10847 < 120; ++i10847) {
        r544[i10847] = add32(r541[i10847], r543[0]);
    }
    /* select_n [select_n] -> r545 */
    for (long i10848 = 0; i10848 < 120; ++i10848) {
        r545[i10848] = r542[i10848] == 0 ? r541[i10848] : (r544[i10848]);
    }
    /* broadcast [broadcast_in_dim] -> r546 */
    for (long i10849 = 0; i10849 < 120; ++i10849) {
        long t10851 = i10849;
        long c108500 = t10851 / 120; t10851 %= 120;
        long c108501 = t10851 / 6; t10851 %= 6;
        long c108502 = t10851 / 1; t10851 %= 1;
        long c108503 = t10851;
        r546[i10849] = r545[c108501 * 6 + c108502 * 1];
    }
    /* gather [gather] -> r547 */
    for (long i10852 = 0; i10852 < 120; ++i10852) {
        long t10854 = i10852;
        long c108530 = t10854 / 120; t10854 %= 120;
        long c108531 = t10854 / 6; t10854 %= 6;
        long c108532 = t10854;
        long row10855 = c108530 * 120 + c108531 * 6 + c108532 * 1;
        long s10856 = clamp_start((long)r546[row10855 + 0], 46, 1);
        r547[i10852] = r532[c108530 * 46 + s10856 * 1];
    }
    /* mov [device_put] -> r548 */
    memcpy(r548, r19, sizeof(int32_t) * 6);
    /* broadcast [broadcast_in_dim] -> r549 */
    for (long i10857 = 0; i10857 < 6; ++i10857) {
        long t10859 = i10857;
        long c108580 = t10859 / 6; t10859 %= 6;
        long c108581 = t10859 / 6; t10859 %= 6;
        long c108582 = t10859;
        r549[i10857] = r548[c108582 * 1];
    }
    /* add [add] -> r550 */
    for (long i10860 = 0; i10860 < 120; ++i10860) {
        long t10862 = i10860;
        long c108610 = t10862 / 120; t10862 %= 120;
        long c108611 = t10862 / 6; t10862 %= 6;
        long c108612 = t10862;
        r550[i10860] = add32(r549[c108612 * 1], r547[c108611 * 6 + c108612 * 1]);
    }
    /* convert [convert_element_type] -> r551 */
    for (long i10863 = 0; i10863 < 1; ++i10863) {
        r551[i10863] = (int32_t)r49[0];
    }
    /* max [max] -> r552 */
    for (long i10864 = 0; i10864 < 120; ++i10864) {
        r552[i10864] = max32(r551[0], r550[i10864]);
    }
    /* convert [convert_element_type] -> r553 */
    for (long i10865 = 0; i10865 < 1; ++i10865) {
        r553[i10865] = (int32_t)r50[0];
    }
    /* min [min] -> r554 */
    for (long i10866 = 0; i10866 < 120; ++i10866) {
        r554[i10866] = min32(r553[0], r552[i10866]);
    }
    /* broadcast [broadcast_in_dim] -> r555 */
    for (long i10867 = 0; i10867 < 6; ++i10867) {
        long t10869 = i10867;
        long c108680 = t10869 / 6; t10869 %= 6;
        long c108681 = t10869 / 6; t10869 %= 6;
        long c108682 = t10869;
        r555[i10867] = r548[c108682 * 1];
    }
    /* sub [sub] -> r556 */
    for (long i10870 = 0; i10870 < 120; ++i10870) {
        long t10872 = i10870;
        long c108710 = t10872 / 120; t10872 %= 120;
        long c108711 = t10872 / 6; t10872 %= 6;
        long c108712 = t10872;
        r556[i10870] = sub32(r555[c108712 * 1], r547[c108711 * 6 + c108712 * 1]);
    }
    /* convert [convert_element_type] -> r557 */
    for (long i10873 = 0; i10873 < 1; ++i10873) {
        r557[i10873] = (int32_t)r49[0];
    }
    /* max [max] -> r558 */
    for (long i10874 = 0; i10874 < 120; ++i10874) {
        r558[i10874] = max32(r557[0], r556[i10874]);
    }
    /* convert [convert_element_type] -> r559 */
    for (long i10875 = 0; i10875 < 1; ++i10875) {
        r559[i10875] = (int32_t)r50[0];
    }
    /* min [min] -> r560 */
    for (long i10876 = 0; i10876 < 120; ++i10876) {
        r560[i10876] = min32(r559[0], r558[i10876]);
    }
    /* abs [abs] -> r561 */
    for (long i10877 = 0; i10877 < 120; ++i10877) {
        r561[i10877] = abs32(r554[i10877]);
    }
    /* reduce_max [reduce_max] -> r562 */
    for (long i10878 = 0; i10878 < 20; ++i10878) {
        r562[i10878] = (-2147483647 - 1);
    }
    for (long i10879 = 0; i10879 < 120; ++i10879) {
        long t10881 = i10879;
        long c108800 = t10881 / 120; t10881 %= 120;
        long c108801 = t10881 / 6; t10881 %= 6;
        long c108802 = t10881;
        r562[c108800 * 20 + c108801 * 1] = max32(r562[c108800 * 20 + c108801 * 1], r561[i10879]);
    }
    /* sub [sub] -> r563 */
    for (long i10882 = 0; i10882 < 20; ++i10882) {
        r563[i10882] = sub32(r562[i10882], r62[0]);
    }
    /* loop [scan] -> r585 */
    memcpy(r564, r554, sizeof(int32_t) * 120);
    memcpy(r565, r62, sizeof(int32_t) * 1);
    memcpy(r566, r40, sizeof(int32_t) * 1);
    memcpy(r567, r563, sizeof(int32_t) * 20);
    memcpy(r568, r562, sizeof(int32_t) * 20);
    for (long t10883 = 0; t10883 < 12; ++t10883) {
        /* add [add] -> r569 */
        for (long i11884 = 0; i11884 < 1; ++i11884) {
            r569[i11884] = add32(r566[0], r30[0]);
        }
        /* add [add] -> r570 */
        for (long i11885 = 0; i11885 < 20; ++i11885) {
            r570[i11885] = add32(r567[i11885], r568[i11885]);
        }
        /* shra [shift_right_arithmetic] -> r571 */
        for (long i11886 = 0; i11886 < 20; ++i11886) {
            r571[i11886] = asr32(r570[i11886], 1);
        }
        /* broadcast [broadcast_in_dim] -> r572 */
        for (long i11887 = 0; i11887 < 20; ++i11887) {
            long t11889 = i11887;
            long c118880 = t11889 / 20; t11889 %= 20;
            long c118881 = t11889 / 1; t11889 %= 1;
            long c118882 = t11889;
            r572[i11887] = r571[c118881 * 1];
        }
        /* sub [sub] -> r573 */
        for (long i11890 = 0; i11890 < 120; ++i11890) {
            long t11892 = i11890;
            long c118910 = t11892 / 120; t11892 %= 120;
            long c118911 = t11892 / 6; t11892 %= 6;
            long c118912 = t11892;
            r573[i11890] = sub32(r564[c118911 * 6 + c118912 * 1], r572[c118911 * 1]);
        }
        /* max [max] -> r574 */
        for (long i11893 = 0; i11893 < 120; ++i11893) {
            r574[i11893] = max32(r573[i11893], r40[0]);
        }
        /* reduce_sum [reduce_sum] -> r575 */
        for (long i11894 = 0; i11894 < 20; ++i11894) {
            r575[i11894] = 0;
        }
        for (long i11895 = 0; i11895 < 120; ++i11895) {
            long t11897 = i11895;
            long c118960 = t11897 / 120; t11897 %= 120;
            long c118961 = t11897 / 6; t11897 %= 6;
            long c118962 = t11897;
            r575[c118960 * 20 + c118961 * 1] = add32(r575[c118960 * 20 + c118961 * 1], r574[i11895]);
        }
        /* neg [neg] -> r576 */
        for (long i11898 = 0; i11898 < 120; ++i11898) {
            r576[i11898] = neg32(r564[i11898]);
        }
        /* broadcast [broadcast_in_dim] -> r577 */
        for (long i11899 = 0; i11899 < 20; ++i11899) {
            long t11901 = i11899;
            long c119000 = t11901 / 20; t11901 %= 20;
            long c119001 = t11901 / 1; t11901 %= 1;
            long c119002 = t11901;
            r577[i11899] = r571[c119001 * 1];
        }
        /* sub [sub] -> r578 */
        for (long i11902 = 0; i11902 < 120; ++i11902) {
            long t11904 = i11902;
            long c119030 = t11904 / 120; t11904 %= 120;
            long c119031 = t11904 / 6; t11904 %= 6;
            long c119032 = t11904;
            r578[i11902] = sub32(r576[c119031 * 6 + c119032 * 1], r577[c119031 * 1]);
        }
        /* max [max] -> r579 */
        for (long i11905 = 0; i11905 < 120; ++i11905) {
            r579[i11905] = max32(r578[i11905], r40[0]);
        }
        /* reduce_sum [reduce_sum] -> r580 */
        for (long i11906 = 0; i11906 < 20; ++i11906) {
            r580[i11906] = 0;
        }
        for (long i11907 = 0; i11907 < 120; ++i11907) {
            long t11909 = i11907;
            long c119080 = t11909 / 120; t11909 %= 120;
            long c119081 = t11909 / 6; t11909 %= 6;
            long c119082 = t11909;
            r580[c119080 * 20 + c119081 * 1] = add32(r580[c119080 * 20 + c119081 * 1], r579[i11907]);
        }
        /* add [add] -> r581 */
        for (long i11910 = 0; i11910 < 20; ++i11910) {
            r581[i11910] = add32(r575[i11910], r580[i11910]);
        }
        /* gt [gt] -> r582 */
        for (long i11911 = 0; i11911 < 20; ++i11911) {
            r582[i11911] = r581[i11911] > r565[0] ? 1 : 0;
        }
        /* select_n [select_n] -> r583 */
        for (long i11912 = 0; i11912 < 20; ++i11912) {
            r583[i11912] = r582[i11912] == 0 ? r567[i11912] : (r571[i11912]);
        }
        /* select_n [select_n] -> r584 */
        for (long i11913 = 0; i11913 < 20; ++i11913) {
            r584[i11913] = r582[i11913] == 0 ? r571[i11913] : (r568[i11913]);
        }
        memcpy(r566, r569, sizeof(int32_t) * 1);
        memcpy(r567, r583, sizeof(int32_t) * 20);
        memcpy(r568, r584, sizeof(int32_t) * 20);
    }
    memcpy(r585, r566, sizeof(int32_t) * 1);
    memcpy(r586, r567, sizeof(int32_t) * 20);
    memcpy(r587, r568, sizeof(int32_t) * 20);
    /* abs [abs] -> r588 */
    for (long i11914 = 0; i11914 < 120; ++i11914) {
        r588[i11914] = abs32(r560[i11914]);
    }
    /* reduce_max [reduce_max] -> r589 */
    for (long i11915 = 0; i11915 < 20; ++i11915) {
        r589[i11915] = (-2147483647 - 1);
    }
    for (long i11916 = 0; i11916 < 120; ++i11916) {
        long t11918 = i11916;
        long c119170 = t11918 / 120; t11918 %= 120;
        long c119171 = t11918 / 6; t11918 %= 6;
        long c119172 = t11918;
        r589[c119170 * 20 + c119171 * 1] = max32(r589[c119170 * 20 + c119171 * 1], r588[i11916]);
    }
    /* sub [sub] -> r590 */
    for (long i11919 = 0; i11919 < 20; ++i11919) {
        r590[i11919] = sub32(r589[i11919], r62[0]);
    }
    /* loop [scan] -> r612 */
    memcpy(r591, r560, sizeof(int32_t) * 120);
    memcpy(r592, r62, sizeof(int32_t) * 1);
    memcpy(r593, r40, sizeof(int32_t) * 1);
    memcpy(r594, r590, sizeof(int32_t) * 20);
    memcpy(r595, r589, sizeof(int32_t) * 20);
    for (long t11920 = 0; t11920 < 12; ++t11920) {
        /* add [add] -> r596 */
        for (long i12921 = 0; i12921 < 1; ++i12921) {
            r596[i12921] = add32(r593[0], r30[0]);
        }
        /* add [add] -> r597 */
        for (long i12922 = 0; i12922 < 20; ++i12922) {
            r597[i12922] = add32(r594[i12922], r595[i12922]);
        }
        /* shra [shift_right_arithmetic] -> r598 */
        for (long i12923 = 0; i12923 < 20; ++i12923) {
            r598[i12923] = asr32(r597[i12923], 1);
        }
        /* broadcast [broadcast_in_dim] -> r599 */
        for (long i12924 = 0; i12924 < 20; ++i12924) {
            long t12926 = i12924;
            long c129250 = t12926 / 20; t12926 %= 20;
            long c129251 = t12926 / 1; t12926 %= 1;
            long c129252 = t12926;
            r599[i12924] = r598[c129251 * 1];
        }
        /* sub [sub] -> r600 */
        for (long i12927 = 0; i12927 < 120; ++i12927) {
            long t12929 = i12927;
            long c129280 = t12929 / 120; t12929 %= 120;
            long c129281 = t12929 / 6; t12929 %= 6;
            long c129282 = t12929;
            r600[i12927] = sub32(r591[c129281 * 6 + c129282 * 1], r599[c129281 * 1]);
        }
        /* max [max] -> r601 */
        for (long i12930 = 0; i12930 < 120; ++i12930) {
            r601[i12930] = max32(r600[i12930], r40[0]);
        }
        /* reduce_sum [reduce_sum] -> r602 */
        for (long i12931 = 0; i12931 < 20; ++i12931) {
            r602[i12931] = 0;
        }
        for (long i12932 = 0; i12932 < 120; ++i12932) {
            long t12934 = i12932;
            long c129330 = t12934 / 120; t12934 %= 120;
            long c129331 = t12934 / 6; t12934 %= 6;
            long c129332 = t12934;
            r602[c129330 * 20 + c129331 * 1] = add32(r602[c129330 * 20 + c129331 * 1], r601[i12932]);
        }
        /* neg [neg] -> r603 */
        for (long i12935 = 0; i12935 < 120; ++i12935) {
            r603[i12935] = neg32(r591[i12935]);
        }
        /* broadcast [broadcast_in_dim] -> r604 */
        for (long i12936 = 0; i12936 < 20; ++i12936) {
            long t12938 = i12936;
            long c129370 = t12938 / 20; t12938 %= 20;
            long c129371 = t12938 / 1; t12938 %= 1;
            long c129372 = t12938;
            r604[i12936] = r598[c129371 * 1];
        }
        /* sub [sub] -> r605 */
        for (long i12939 = 0; i12939 < 120; ++i12939) {
            long t12941 = i12939;
            long c129400 = t12941 / 120; t12941 %= 120;
            long c129401 = t12941 / 6; t12941 %= 6;
            long c129402 = t12941;
            r605[i12939] = sub32(r603[c129401 * 6 + c129402 * 1], r604[c129401 * 1]);
        }
        /* max [max] -> r606 */
        for (long i12942 = 0; i12942 < 120; ++i12942) {
            r606[i12942] = max32(r605[i12942], r40[0]);
        }
        /* reduce_sum [reduce_sum] -> r607 */
        for (long i12943 = 0; i12943 < 20; ++i12943) {
            r607[i12943] = 0;
        }
        for (long i12944 = 0; i12944 < 120; ++i12944) {
            long t12946 = i12944;
            long c129450 = t12946 / 120; t12946 %= 120;
            long c129451 = t12946 / 6; t12946 %= 6;
            long c129452 = t12946;
            r607[c129450 * 20 + c129451 * 1] = add32(r607[c129450 * 20 + c129451 * 1], r606[i12944]);
        }
        /* add [add] -> r608 */
        for (long i12947 = 0; i12947 < 20; ++i12947) {
            r608[i12947] = add32(r602[i12947], r607[i12947]);
        }
        /* gt [gt] -> r609 */
        for (long i12948 = 0; i12948 < 20; ++i12948) {
            r609[i12948] = r608[i12948] > r592[0] ? 1 : 0;
        }
        /* select_n [select_n] -> r610 */
        for (long i12949 = 0; i12949 < 20; ++i12949) {
            r610[i12949] = r609[i12949] == 0 ? r594[i12949] : (r598[i12949]);
        }
        /* select_n [select_n] -> r611 */
        for (long i12950 = 0; i12950 < 20; ++i12950) {
            r611[i12950] = r609[i12950] == 0 ? r598[i12950] : (r595[i12950]);
        }
        memcpy(r593, r596, sizeof(int32_t) * 1);
        memcpy(r594, r610, sizeof(int32_t) * 20);
        memcpy(r595, r611, sizeof(int32_t) * 20);
    }
    memcpy(r612, r593, sizeof(int32_t) * 1);
    memcpy(r613, r594, sizeof(int32_t) * 20);
    memcpy(r614, r595, sizeof(int32_t) * 20);
    /* sub [sub] -> r615 */
    for (long i12951 = 0; i12951 < 20; ++i12951) {
        r615[i12951] = sub32(r587[i12951], r614[i12951]);
    }
    /* shra [shift_right_arithmetic] -> r616 */
    for (long i12952 = 0; i12952 < 20; ++i12952) {
        r616[i12952] = asr32(r615[i12952], 1);
    }
    /* convert [convert_element_type] -> r617 */
    for (long i12953 = 0; i12953 < 1; ++i12953) {
        r617[i12953] = (int32_t)r222[0];
    }
    /* max [max] -> r618 */
    for (long i12954 = 0; i12954 < 20; ++i12954) {
        r618[i12954] = max32(r617[0], r616[i12954]);
    }
    /* convert [convert_element_type] -> r619 */
    for (long i12955 = 0; i12955 < 1; ++i12955) {
        r619[i12955] = (int32_t)r223[0];
    }
    /* min [min] -> r620 */
    for (long i12956 = 0; i12956 < 20; ++i12956) {
        r620[i12956] = min32(r619[0], r618[i12956]);
    }
    /* sub [sub] -> r621 */
    for (long i12957 = 0; i12957 < 1; ++i12957) {
        r621[i12957] = sub32(r427[i12957], r528[i12957]);
    }
    /* add [add] -> r622 */
    for (long i12958 = 0; i12958 < 1; ++i12958) {
        r622[i12958] = add32(r621[i12958], r30[0]);
    }
    /* max [max] -> r623 */
    for (long i12959 = 0; i12959 < 1; ++i12959) {
        r623[i12959] = max32(r622[i12959], r40[0]);
    }
    /* shra [shift_right_arithmetic] -> r624 */
    for (long i12960 = 0; i12960 < 1; ++i12960) {
        r624[i12960] = asr32(r623[i12960], 1);
    }
    /* concat [concatenate] -> r625 */
    for (long i12961 = 0; i12961 < 15; ++i12961) {
        long t12963 = i12961;
        long c129620 = t12963 / 15; t12963 %= 15;
        long c129621 = t12963;
        r625[c129620 * 35 + (c129621 + 0) * 1] = r3[i12961];
    }
    for (long i12964 = 0; i12964 < 20; ++i12964) {
        long t12966 = i12964;
        long c129650 = t12966 / 20; t12966 %= 20;
        long c129651 = t12966;
        r625[c129650 * 35 + (c129651 + 15) * 1] = r620[i12964];
    }
    /* shl [shift_left] -> r626 */
    for (long i12967 = 0; i12967 < 35; ++i12967) {
        r626[i12967] = shl32(r625[i12967], 1);
    }
    /* mov [device_put] -> r627 */
    memcpy(r627, r18, sizeof(int32_t) * 80);
    /* rev [rev] -> r628 */
    for (long i12968 = 0; i12968 < 80; ++i12968) {
        long t12970 = i12968;
        long c129690 = t12970 / 16; t12970 %= 16;
        long c129691 = t12970;
        r628[i12968] = r627[c129690 * 16 + (16 - 1 - c129691) * 1];
    }
    /* reshape [reshape] -> r629 */
    memcpy(r629, r628, sizeof(int32_t) * 80);
    /* iota [iota] -> r630 */
    for (long i12971 = 0; i12971 < 20; ++i12971) {
        long t12973 = i12971;
        long c129720 = t12973;
        r630[i12971] = (int32_t)c129720;
    }
    /* broadcast [broadcast_in_dim] -> r631 */
    for (long i12974 = 0; i12974 < 20; ++i12974) {
        long t12976 = i12974;
        long c129750 = t12976 / 1; t12976 %= 1;
        long c129751 = t12976;
        r631[i12974] = r630[c129750 * 1];
    }
    /* iota [iota] -> r632 */
    for (long i12977 = 0; i12977 < 16; ++i12977) {
        long t12979 = i12977;
        long c129780 = t12979;
        r632[i12977] = (int32_t)c129780;
    }
    /* broadcast [broadcast_in_dim] -> r633 */
    for (long i12980 = 0; i12980 < 16; ++i12980) {
        long t12982 = i12980;
        long c129810 = t12982 / 16; t12982 %= 16;
        long c129811 = t12982;
        r633[i12980] = r632[c129811 * 1];
    }
    /* add [add] -> r634 */
    for (long i12983 = 0; i12983 < 320; ++i12983) {
        long t12985 = i12983;
        long c129840 = t12985 / 16; t12985 %= 16;
        long c129841 = t12985;
        r634[i12983] = add32(r631[c129840 * 1], r633[c129841 * 1]);
    }
    /* lt [lt] -> r635 */
    for (long i12986 = 0; i12986 < 320; ++i12986) {
        r635[i12986] = r634[i12986] < r40[0] ? 1 : 0;
    }
    /* add [add] -> r637 */
    for (long i12987 = 0; i12987 < 320; ++i12987) {
        r637[i12987] = add32(r634[i12987], r636[0]);
    }
    /* select_n [select_n] -> r638 */
    for (long i12988 = 0; i12988 < 320; ++i12988) {
        r638[i12988] = r635[i12988] == 0 ? r634[i12988] : (r637[i12988]);
    }
    /* broadcast [broadcast_in_dim] -> r639 */
    for (long i12989 = 0; i12989 < 320; ++i12989) {
        long t12991 = i12989;
        long c129900 = t12991 / 16; t12991 %= 16;
        long c129901 = t12991 / 1; t12991 %= 1;
        long c129902 = t12991;
        r639[i12989] = r638[c129900 * 16 + c129901 * 1];
    }
    /* gather [gather] -> r640 */
    for (long i12992 = 0; i12992 < 320; ++i12992) {
        long t12994 = i12992;
        long c129930 = t12994 / 320; t12994 %= 320;
        long c129931 = t12994 / 16; t12994 %= 16;
        long c129932 = t12994;
        long row12995 = c129931 * 16 + c129932 * 1;
        long s12996 = clamp_start((long)r639[row12995 + 0], 35, 1);
        r640[i12992] = r626[c129930 * 35 + s12996 * 1];
    }
    /* broadcast [broadcast_in_dim] -> r641 */
    for (long i12997 = 0; i12997 < 320; ++i12997) {
        long t12999 = i12997;
        long c129980 = t12999 / 320; t12999 %= 320;
        long c129981 = t12999 / 320; t12999 %= 320;
        long c129982 = t12999 / 16; t12999 %= 16;
        long c129983 = t12999;
        r641[i12997] = r640[c129982 * 16 + c129983 * 1];
    }
    /* add [add] -> r642 */
    for (long i13000 = 0; i13000 < 1600; ++i13000) {
        long t13002 = i13000;
        long c130010 = t13002 / 320; t13002 %= 320;
        long c130011 = t13002 / 320; t13002 %= 320;
        long c130012 = t13002 / 16; t13002 %= 16;
        long c130013 = t13002;
        r642[i13000] = add32(r629[c130010 * 16 + c130013 * 1], r641[c130012 * 16 + c130013 * 1]);
    }
    /* convert [convert_element_type] -> r643 */
    for (long i13003 = 0; i13003 < 1; ++i13003) {
        r643[i13003] = (int32_t)r49[0];
    }
    /* max [max] -> r644 */
    for (long i13004 = 0; i13004 < 1600; ++i13004) {
        r644[i13004] = max32(r643[0], r642[i13004]);
    }
    /* convert [convert_element_type] -> r645 */
    for (long i13005 = 0; i13005 < 1; ++i13005) {
        r645[i13005] = (int32_t)r50[0];
    }
    /* min [min] -> r646 */
    for (long i13006 = 0; i13006 < 1600; ++i13006) {
        r646[i13006] = min32(r645[0], r644[i13006]);
    }
    /* sub [sub] -> r647 */
    for (long i13007 = 0; i13007 < 1600; ++i13007) {
        long t13009 = i13007;
        long c130080 = t13009 / 320; t13009 %= 320;
        long c130081 = t13009 / 320; t13009 %= 320;
        long c130082 = t13009 / 16; t13009 %= 16;
        long c130083 = t13009;
        r647[i13007] = sub32(r629[c130080 * 16 + c130083 * 1], r641[c130082 * 16 + c130083 * 1]);
    }
    /* convert [convert_element_type] -> r648 */
    for (long i13010 = 0; i13010 < 1; ++i13010) {
        r648[i13010] = (int32_t)r49[0];
    }
    /* max [max] -> r649 */
    for (long i13011 = 0; i13011 < 1600; ++i13011) {
        r649[i13011] = max32(r648[0], r647[i13011]);
    }
    /* convert [convert_element_type] -> r650 */
    for (long i13012 = 0; i13012 < 1; ++i13012) {
        r650[i13012] = (int32_t)r50[0];
    }
    /* min [min] -> r651 */
    for (long i13013 = 0; i13013 < 1600; ++i13013) {
        r651[i13013] = min32(r650[0], r649[i13013]);
    }
    /* abs [abs] -> r652 */
    for (long i13014 = 0; i13014 < 1600; ++i13014) {
        r652[i13014] = abs32(r646[i13014]);
    }
    /* reduce_max [reduce_max] -> r653 */
    for (long i13015 = 0; i13015 < 100; ++i13015) {
        r653[i13015] = (-2147483647 - 1);
    }
    for (long i13016 = 0; i13016 < 1600; ++i13016) {
        long t13018 = i13016;
        long c130170 = t13018 / 320; t13018 %= 320;
        long c130171 = t13018 / 320; t13018 %= 320;
        long c130172 = t13018 / 16; t13018 %= 16;
        long c130173 = t13018;
        r653[c130170 * 20 + c130171 * 20 + c130172 * 1] = max32(r653[c130170 * 20 + c130171 * 20 + c130172 * 1], r652[i13016]);
    }
    /* sub [sub] -> r654 */
    for (long i13019 = 0; i13019 < 100; ++i13019) {
        r654[i13019] = sub32(r653[i13019], r62[0]);
    }
    /* loop [scan] -> r676 */
    memcpy(r655, r646, sizeof(int32_t) * 1600);
    memcpy(r656, r62, sizeof(int32_t) * 1);
    memcpy(r657, r40, sizeof(int32_t) * 1);
    memcpy(r658, r654, sizeof(int32_t) * 100);
    memcpy(r659, r653, sizeof(int32_t) * 100);
    for (long t13020 = 0; t13020 < 12; ++t13020) {
        /* add [add] -> r660 */
        for (long i14021 = 0; i14021 < 1; ++i14021) {
            r660[i14021] = add32(r657[0], r30[0]);
        }
        /* add [add] -> r661 */
        for (long i14022 = 0; i14022 < 100; ++i14022) {
            r661[i14022] = add32(r658[i14022], r659[i14022]);
        }
        /* shra [shift_right_arithmetic] -> r662 */
        for (long i14023 = 0; i14023 < 100; ++i14023) {
            r662[i14023] = asr32(r661[i14023], 1);
        }
        /* broadcast [broadcast_in_dim] -> r663 */
        for (long i14024 = 0; i14024 < 100; ++i14024) {
            long t14026 = i14024;
            long c140250 = t14026 / 20; t14026 %= 20;
            long c140251 = t14026 / 20; t14026 %= 20;
            long c140252 = t14026 / 1; t14026 %= 1;
            long c140253 = t14026;
            r663[i14024] = r662[c140250 * 20 + c140252 * 1];
        }
        /* sub [sub] -> r664 */
        for (long i14027 = 0; i14027 < 1600; ++i14027) {
            long t14029 = i14027;
            long c140280 = t14029 / 320; t14029 %= 320;
            long c140281 = t14029 / 320; t14029 %= 320;
            long c140282 = t14029 / 16; t14029 %= 16;
            long c140283 = t14029;
            r664[i14027] = sub32(r655[c140280 * 320 + c140282 * 16 + c140283 * 1], r663[c140280 * 20 + c140282 * 1]);
        }
        /* max [max] -> r665 */
        for (long i14030 = 0; i14030 < 1600; ++i14030) {
            r665[i14030] = max32(r664[i14030], r40[0]);
        }
        /* reduce_sum [reduce_sum] -> r666 */
        for (long i14031 = 0; i14031 < 100; ++i14031) {
            r666[i14031] = 0;
        }
        for (long i14032 = 0; i14032 < 1600; ++i14032) {
            long t14034 = i14032;
            long c140330 = t14034 / 320; t14034 %= 320;
            long c140331 = t14034 / 320; t14034 %= 320;
            long c140332 = t14034 / 16; t14034 %= 16;
            long c140333 = t14034;
            r666[c140330 * 20 + c140331 * 20 + c140332 * 1] = add32(r666[c140330 * 20 + c140331 * 20 + c140332 * 1], r665[i14032]);
        }
        /* neg [neg] -> r667 */
        for (long i14035 = 0; i14035 < 1600; ++i14035) {
            r667[i14035] = neg32(r655[i14035]);
        }
        /* broadcast [broadcast_in_dim] -> r668 */
        for (long i14036 = 0; i14036 < 100; ++i14036) {
            long t14038 = i14036;
            long c140370 = t14038 / 20; t14038 %= 20;
            long c140371 = t14038 / 20; t14038 %= 20;
            long c140372 = t14038 / 1; t14038 %= 1;
            long c140373 = t14038;
            r668[i14036] = r662[c140370 * 20 + c140372 * 1];
        }
        /* sub [sub] -> r669 */
        for (long i14039 = 0; i14039 < 1600; ++i14039) {
            long t14041 = i14039;
            long c140400 = t14041 / 320; t14041 %= 320;
            long c140401 = t14041 / 320; t14041 %= 320;
            long c140402 = t14041 / 16; t14041 %= 16;
            long c140403 = t14041;
            r669[i14039] = sub32(r667[c140400 * 320 + c140402 * 16 + c140403 * 1], r668[c140400 * 20 + c140402 * 1]);
        }
        /* max [max] -> r670 */
        for (long i14042 = 0; i14042 < 1600; ++i14042) {
            r670[i14042] = max32(r669[i14042], r40[0]);
        }
        /* reduce_sum [reduce_sum] -> r671 */
        for (long i14043 = 0; i14043 < 100; ++i14043) {
            r671[i14043] = 0;
        }
        for (long i14044 = 0; i14044 < 1600; ++i14044) {
            long t14046 = i14044;
            long c140450 = t14046 / 320; t14046 %= 320;
            long c140451 = t14046 / 320; t14046 %= 320;
            long c140452 = t14046 / 16; t14046 %= 16;
            long c140453 = t14046;
            r671[c140450 * 20 + c140451 * 20 + c140452 * 1] = add32(r671[c140450 * 20 + c140451 * 20 + c140452 * 1], r670[i14044]);
        }
        /* add [add] -> r672 */
        for (long i14047 = 0; i14047 < 100; ++i14047) {
            r672[i14047] = add32(r666[i14047], r671[i14047]);
        }
        /* gt [gt] -> r673 */
        for (long i14048 = 0; i14048 < 100; ++i14048) {
            r673[i14048] = r672[i14048] > r656[0] ? 1 : 0;
        }
        /* select_n [select_n] -> r674 */
        for (long i14049 = 0; i14049 < 100; ++i14049) {
            r674[i14049] = r673[i14049] == 0 ? r658[i14049] : (r662[i14049]);
        }
        /* select_n [select_n] -> r675 */
        for (long i14050 = 0; i14050 < 100; ++i14050) {
            r675[i14050] = r673[i14050] == 0 ? r662[i14050] : (r659[i14050]);
        }
        memcpy(r657, r660, sizeof(int32_t) * 1);
        memcpy(r658, r674, sizeof(int32_t) * 100);
        memcpy(r659, r675, sizeof(int32_t) * 100);
    }
    memcpy(r676, r657, sizeof(int32_t) * 1);
    memcpy(r677, r658, sizeof(int32_t) * 100);
    memcpy(r678, r659, sizeof(int32_t) * 100);
    /* abs [abs] -> r679 */
    for (long i14051 = 0; i14051 < 1600; ++i14051) {
        r679[i14051] = abs32(r651[i14051]);
    }
    /* reduce_max [reduce_max] -> r680 */
    for (long i14052 = 0; i14052 < 100; ++i14052) {
        r680[i14052] = (-2147483647 - 1);
    }
    for (long i14053 = 0; i14053 < 1600; ++i14053) {
        long t14055 = i14053;
        long c140540 = t14055 / 320; t14055 %= 320;
        long c140541 = t14055 / 320; t14055 %= 320;
        long c140542 = t14055 / 16; t14055 %= 16;
        long c140543 = t14055;
        r680[c140540 * 20 + c140541 * 20 + c140542 * 1] = max32(r680[c140540 * 20 + c140541 * 20 + c140542 * 1], r679[i14053]);
    }
    /* sub [sub] -> r681 */
    for (long i14056 = 0; i14056 < 100; ++i14056) {
        r681[i14056] = sub32(r680[i14056], r62[0]);
    }
    /* loop [scan] -> r703 */
    memcpy(r682, r651, sizeof(int32_t) * 1600);
    memcpy(r683, r62, sizeof(int32_t) * 1);
    memcpy(r684, r40, sizeof(int32_t) * 1);
    memcpy(r685, r681, sizeof(int32_t) * 100);
    memcpy(r686, r680, sizeof(int32_t) * 100);
    for (long t14057 = 0; t14057 < 12; ++t14057) {
        /* add [add] -> r687 */
        for (long i15058 = 0; i15058 < 1; ++i15058) {
            r687[i15058] = add32(r684[0], r30[0]);
        }
        /* add [add] -> r688 */
        for (long i15059 = 0; i15059 < 100; ++i15059) {
            r688[i15059] = add32(r685[i15059], r686[i15059]);
        }
        /* shra [shift_right_arithmetic] -> r689 */
        for (long i15060 = 0; i15060 < 100; ++i15060) {
            r689[i15060] = asr32(r688[i15060], 1);
        }
        /* broadcast [broadcast_in_dim] -> r690 */
        for (long i15061 = 0; i15061 < 100; ++i15061) {
            long t15063 = i15061;
            long c150620 = t15063 / 20; t15063 %= 20;
            long c150621 = t15063 / 20; t15063 %= 20;
            long c150622 = t15063 / 1; t15063 %= 1;
            long c150623 = t15063;
            r690[i15061] = r689[c150620 * 20 + c150622 * 1];
        }
        /* sub [sub] -> r691 */
        for (long i15064 = 0; i15064 < 1600; ++i15064) {
            long t15066 = i15064;
            long c150650 = t15066 / 320; t15066 %= 320;
            long c150651 = t15066 / 320; t15066 %= 320;
            long c150652 = t15066 / 16; t15066 %= 16;
            long c150653 = t15066;
            r691[i15064] = sub32(r682[c150650 * 320 + c150652 * 16 + c150653 * 1], r690[c150650 * 20 + c150652 * 1]);
        }
        /* max [max] -> r692 */
        for (long i15067 = 0; i15067 < 1600; ++i15067) {
            r692[i15067] = max32(r691[i15067], r40[0]);
        }
        /* reduce_sum [reduce_sum] -> r693 */
        for (long i15068 = 0; i15068 < 100; ++i15068) {
            r693[i15068] = 0;
        }
        for (long i15069 = 0; i15069 < 1600; ++i15069) {
            long t15071 = i15069;
            long c150700 = t15071 / 320; t15071 %= 320;
            long c150701 = t15071 / 320; t15071 %= 320;
            long c150702 = t15071 / 16; t15071 %= 16;
            long c150703 = t15071;
            r693[c150700 * 20 + c150701 * 20 + c150702 * 1] = add32(r693[c150700 * 20 + c150701 * 20 + c150702 * 1], r692[i15069]);
        }
        /* neg [neg] -> r694 */
        for (long i15072 = 0; i15072 < 1600; ++i15072) {
            r694[i15072] = neg32(r682[i15072]);
        }
        /* broadcast [broadcast_in_dim] -> r695 */
        for (long i15073 = 0; i15073 < 100; ++i15073) {
            long t15075 = i15073;
            long c150740 = t15075 / 20; t15075 %= 20;
            long c150741 = t15075 / 20; t15075 %= 20;
            long c150742 = t15075 / 1; t15075 %= 1;
            long c150743 = t15075;
            r695[i15073] = r689[c150740 * 20 + c150742 * 1];
        }
        /* sub [sub] -> r696 */
        for (long i15076 = 0; i15076 < 1600; ++i15076) {
            long t15078 = i15076;
            long c150770 = t15078 / 320; t15078 %= 320;
            long c150771 = t15078 / 320; t15078 %= 320;
            long c150772 = t15078 / 16; t15078 %= 16;
            long c150773 = t15078;
            r696[i15076] = sub32(r694[c150770 * 320 + c150772 * 16 + c150773 * 1], r695[c150770 * 20 + c150772 * 1]);
        }
        /* max [max] -> r697 */
        for (long i15079 = 0; i15079 < 1600; ++i15079) {
            r697[i15079] = max32(r696[i15079], r40[0]);
        }
        /* reduce_sum [reduce_sum] -> r698 */
        for (long i15080 = 0; i15080 < 100; ++i15080) {
            r698[i15080] = 0;
        }
        for (long i15081 = 0; i15081 < 1600; ++i15081) {
            long t15083 = i15081;
            long c150820 = t15083 / 320; t15083 %= 320;
            long c150821 = t15083 / 320; t15083 %= 320;
            long c150822 = t15083 / 16; t15083 %= 16;
            long c150823 = t15083;
            r698[c150820 * 20 + c150821 * 20 + c150822 * 1] = add32(r698[c150820 * 20 + c150821 * 20 + c150822 * 1], r697[i15081]);
        }
        /* add [add] -> r699 */
        for (long i15084 = 0; i15084 < 100; ++i15084) {
            r699[i15084] = add32(r693[i15084], r698[i15084]);
        }
        /* gt [gt] -> r700 */
        for (long i15085 = 0; i15085 < 100; ++i15085) {
            r700[i15085] = r699[i15085] > r683[0] ? 1 : 0;
        }
        /* select_n [select_n] -> r701 */
        for (long i15086 = 0; i15086 < 100; ++i15086) {
            r701[i15086] = r700[i15086] == 0 ? r685[i15086] : (r689[i15086]);
        }
        /* select_n [select_n] -> r702 */
        for (long i15087 = 0; i15087 < 100; ++i15087) {
            r702[i15087] = r700[i15087] == 0 ? r689[i15087] : (r686[i15087]);
        }
        memcpy(r684, r687, sizeof(int32_t) * 1);
        memcpy(r685, r701, sizeof(int32_t) * 100);
        memcpy(r686, r702, sizeof(int32_t) * 100);
    }
    memcpy(r703, r684, sizeof(int32_t) * 1);
    memcpy(r704, r685, sizeof(int32_t) * 100);
    memcpy(r705, r686, sizeof(int32_t) * 100);
    /* sub [sub] -> r706 */
    for (long i15088 = 0; i15088 < 100; ++i15088) {
        r706[i15088] = sub32(r678[i15088], r705[i15088]);
    }
    /* transpose [transpose] -> r707 */
    for (long i15089 = 0; i15089 < 100; ++i15089) {
        long t15091 = i15089;
        long c150900 = t15091 / 100; t15091 %= 100;
        long c150901 = t15091 / 20; t15091 %= 20;
        long c150902 = t15091;
        r707[i15089] = r706[c150900 * 20 + c150901 * 20 + c150902 * 1];
    }
    /* broadcast [broadcast_in_dim] -> r708 */
    for (long i15092 = 0; i15092 < 1; ++i15092) {
        long t15094 = i15092;
        long c150930 = t15094 / 1; t15094 %= 1;
        long c150931 = t15094;
        r708[i15092] = r624[0];
    }
    /* max [max] -> r709 */
    for (long i15095 = 0; i15095 < 100; ++i15095) {
        r709[i15095] = max32(r707[i15095], r40[0]);
    }
    /* iota [iota] -> r710 */
    for (long i15096 = 0; i15096 < 100; ++i15096) {
        long t15098 = i15096;
        long c150970 = t15098 / 100; t15098 %= 100;
        long c150971 = t15098 / 20; t15098 %= 20;
        long c150972 = t15098;
        r710[i15096] = (int32_t)c150972;
    }
    /* broadcast [broadcast_in_dim] -> r711 */
    for (long i15099 = 0; i15099 < 1; ++i15099) {
        long t15101 = i15099;
        long c151000 = t15101 / 1; t15101 %= 1;
        long c151001 = t15101 / 1; t15101 %= 1;
        long c151002 = t15101;
        r711[i15099] = r708[0];
    }
    /* lt [lt] -> r712 */
    for (long i15102 = 0; i15102 < 100; ++i15102) {
        long t15104 = i15102;
        long c151030 = t15104 / 100; t15104 %= 100;
        long c151031 = t15104 / 20; t15104 %= 20;
        long c151032 = t15104;
        r712[i15102] = r710[c151031 * 20 + c151032 * 1] < r711[0] ? 1 : 0;
    }
    /* convert [convert_element_type] -> r713 */
    for (long i15105 = 0; i15105 < 1; ++i15105) {
        r713[i15105] = (int32_t)r40[0];
    }
    /* broadcast [broadcast_in_dim] -> r714 */
    for (long i15106 = 0; i15106 < 100; ++i15106) {
        long t15108 = i15106;
        long c151070 = t15108 / 100; t15108 %= 100;
        long c151071 = t15108 / 20; t15108 %= 20;
        long c151072 = t15108;
        r714[i15106] = r713[0];
    }
    /* select_n [select_n] -> r715 */
    for (long i15109 = 0; i15109 < 100; ++i15109) {
        r715[i15109] = r712[i15109] == 0 ? r714[i15109] : (r709[i15109]);
    }
    /* reduce_sum [reduce_sum] -> r716 */
    for (long i15110 = 0; i15110 < 5; ++i15110) {
        r716[i15110] = 0;
    }
    for (long i15111 = 0; i15111 < 100; ++i15111) {
        long t15113 = i15111;
        long c151120 = t15113 / 100; t15113 %= 100;
        long c151121 = t15113 / 20; t15113 %= 20;
        long c151122 = t15113;
        r716[c151120 * 5 + c151121 * 1] = add32(r716[c151120 * 5 + c151121 * 1], r715[i15111]);
    }
    /* shl [shift_left] -> r718 */
    for (long i15114 = 0; i15114 < 5; ++i15114) {
        r718[i15114] = shl32(r716[i15114], 3);
    }
    /* lt [lt] -> r719 */
    for (long i15115 = 0; i15115 < 1; ++i15115) {
        r719[i15115] = r624[i15115] < r40[0] ? 1 : 0;
    }
    /* add [add] -> r720 */
    for (long i15116 = 0; i15116 < 1; ++i15116) {
        r720[i15116] = add32(r624[i15116], r636[0]);
    }
    /* select_n [select_n] -> r721 */
    for (long i15117 = 0; i15117 < 1; ++i15117) {
        r721[i15117] = r719[i15117] == 0 ? r624[i15117] : (r720[i15117]);
    }
    /* broadcast [broadcast_in_dim] -> r722 */
    for (long i15118 = 0; i15118 < 1; ++i15118) {
        long t15120 = i15118;
        long c151190 = t15120 / 1; t15120 %= 1;
        long c151191 = t15120;
        r722[i15118] = r721[0];
    }
    /* gather [gather] -> r723 */
    for (long i15121 = 0; i15121 < 15; ++i15121) {
        long t15123 = i15121;
        long c151220 = t15123 / 15; t15123 %= 15;
        long c151221 = t15123;
        long row15124 = c151220 * 1;
        long s15125 = clamp_start((long)r722[row15124 + 0], 35, 15);
        r723[i15121] = r625[c151220 * 35 + (s15125 + c151221) * 1];
    }
    /* add [add] -> r724 */
    for (long i15126 = 0; i15126 < 1; ++i15126) {
        r724[i15126] = add32(r9[i15126], r624[i15126]);
    }
    /* and [and] -> r725 */
    for (long i15127 = 0; i15127 < 1; ++i15127) {
        r725[i15127] = r9[i15127] & r30[0];
    }
    /* slice [slice] -> r726 */
    for (long i15128 = 0; i15128 < 25; ++i15128) {
        long t15130 = i15128;
        long c151290 = t15130 / 25; t15130 %= 25;
        long c151291 = t15130;
        r726[i15128] = r625[(0 + c151290 * 1) * 35 + (10 + c151291 * 1) * 1];
    }
    /* shl [shift_left] -> r727 */
    for (long i15131 = 0; i15131 < 25; ++i15131) {
        r727[i15131] = shl32(r726[i15131], 1);
    }
    /* convert [convert_element_type] -> r728 */
    for (long i15132 = 0; i15132 < 1; ++i15132) {
        r728[i15132] = (int32_t)r40[0];
    }
    /* pad [pad] -> r729 */
    for (long i15133 = 0; i15133 < 26; ++i15133) {
        r729[i15133] = r728[0];
    }
    for (long i15134 = 0; i15134 < 25; ++i15134) {
        long t15136 = i15134;
        long c151350 = t15136 / 25; t15136 %= 25;
        long c151351 = t15136;
        long d15137 = 0 + c151350 * 1;
        long d15138 = 0 + c151351 * 1;
        if (d15137 >= 0 && d15137 < 1 && d15138 >= 0 && d15138 < 26) r729[d15137 * 26 + d15138 * 1] = r727[i15134];
    }
    /* iota [iota] -> r730 */
    for (long i15139 = 0; i15139 < 10; ++i15139) {
        long t15141 = i15139;
        long c151400 = t15141;
        r730[i15139] = (int32_t)c151400;
    }
    /* shl [shift_left] -> r731 */
    for (long i15142 = 0; i15142 < 10; ++i15142) {
        r731[i15142] = shl32(r730[i15142], 1);
    }
    /* broadcast [broadcast_in_dim] -> r732 */
    for (long i15143 = 0; i15143 < 10; ++i15143) {
        long t15145 = i15143;
        long c151440 = t15145 / 1; t15145 %= 1;
        long c151441 = t15145;
        r732[i15143] = r731[c151440 * 1];
    }
    /* iota [iota] -> r733 */
    for (long i15146 = 0; i15146 < 6; ++i15146) {
        long t15148 = i15146;
        long c151470 = t15148;
        r733[i15146] = (int32_t)c151470;
    }
    /* broadcast [broadcast_in_dim] -> r734 */
    for (long i15149 = 0; i15149 < 6; ++i15149) {
        long t15151 = i15149;
        long c151500 = t15151 / 6; t15151 %= 6;
        long c151501 = t15151;
        r734[i15149] = r733[c151501 * 1];
    }
    /* add [add] -> r735 */
    for (long i15152 = 0; i15152 < 60; ++i15152) {
        long t15154 = i15152;
        long c151530 = t15154 / 6; t15154 %= 6;
        long c151531 = t15154;
        r735[i15152] = add32(r732[c151530 * 1], r734[c151531 * 1]);
    }
    /* broadcast [broadcast_in_dim] -> r736 */
    for (long i15155 = 0; i15155 < 60; ++i15155) {
        long t15157 = i15155;
        long c151560 = t15157 / 60; t15157 %= 60;
        long c151561 = t15157 / 6; t15157 %= 6;
        long c151562 = t15157;
        r736[i15155] = r735[c151561 * 6 + c151562 * 1];
    }
    /* broadcast [broadcast_in_dim] -> r737 */
    for (long i15158 = 0; i15158 < 1; ++i15158) {
        long t15160 = i15158;
        long c151590 = t15160 / 1; t15160 %= 1;
        long c151591 = t15160 / 1; t15160 %= 1;
        long c151592 = t15160;
        r737[i15158] = r725[0];
    }
    /* add [add] -> r738 */
    for (long i15161 = 0; i15161 < 60; ++i15161) {
        long t15163 = i15161;
        long c151620 = t15163 / 60; t15163 %= 60;
        long c151621 = t15163 / 6; t15163 %= 6;
        long c151622 = t15163;
        r738[i15161] = add32(r737[0], r736[c151621 * 6 + c151622 * 1]);
    }
    /* lt [lt] -> r739 */
    for (long i15164 = 0; i15164 < 60; ++i15164) {
        r739[i15164] = r738[i15164] < r40[0] ? 1 : 0;
    }
    /* add [add] -> r741 */
    for (long i15165 = 0; i15165 < 60; ++i15165) {
        r741[i15165] = add32(r738[i15165], r740[0]);
    }
    /* select_n [select_n] -> r742 */
    for (long i15166 = 0; i15166 < 60; ++i15166) {
        r742[i15166] = r739[i15166] == 0 ? r738[i15166] : (r741[i15166]);
    }
    /* broadcast [broadcast_in_dim] -> r743 */
    for (long i15167 = 0; i15167 < 60; ++i15167) {
        long t15169 = i15167;
        long c151680 = t15169 / 60; t15169 %= 60;
        long c151681 = t15169 / 6; t15169 %= 6;
        long c151682 = t15169 / 1; t15169 %= 1;
        long c151683 = t15169;
        r743[i15167] = r742[c151681 * 6 + c151682 * 1];
    }
    /* gather [gather] -> r744 */
    for (long i15170 = 0; i15170 < 60; ++i15170) {
        long t15172 = i15170;
        long c151710 = t15172 / 60; t15172 %= 60;
        long c151711 = t15172 / 6; t15172 %= 6;
        long c151712 = t15172;
        long row15173 = c151710 * 60 + c151711 * 6 + c151712 * 1;
        long s15174 = clamp_start((long)r743[row15173 + 0], 26, 1);
        r744[i15170] = r729[c151710 * 26 + s15174 * 1];
    }
    /* mov [device_put] -> r745 */
    memcpy(r745, r19, sizeof(int32_t) * 6);
    /* broadcast [broadcast_in_dim] -> r746 */
    for (long i15175 = 0; i15175 < 6; ++i15175) {
        long t15177 = i15175;
        long c151760 = t15177 / 6; t15177 %= 6;
        long c151761 = t15177 / 6; t15177 %= 6;
        long c151762 = t15177;
        r746[i15175] = r745[c151762 * 1];
    }
    /* add [add] -> r747 */
    for (long i15178 = 0; i15178 < 60; ++i15178) {
        long t15180 = i15178;
        long c151790 = t15180 / 60; t15180 %= 60;
        long c151791 = t15180 / 6; t15180 %= 6;
        long c151792 = t15180;
        r747[i15178] = add32(r746[c151792 * 1], r744[c151791 * 6 + c151792 * 1]);
    }
    /* convert [convert_element_type] -> r748 */
    for (long i15181 = 0; i15181 < 1; ++i15181) {
        r748[i15181] = (int32_t)r49[0];
    }
    /* max [max] -> r749 */
    for (long i15182 = 0; i15182 < 60; ++i15182) {
        r749[i15182] = max32(r748[0], r747[i15182]);
    }
    /* convert [convert_element_type] -> r750 */
    for (long i15183 = 0; i15183 < 1; ++i15183) {
        r750[i15183] = (int32_t)r50[0];
    }
    /* min [min] -> r751 */
    for (long i15184 = 0; i15184 < 60; ++i15184) {
        r751[i15184] = min32(r750[0], r749[i15184]);
    }
    /* broadcast [broadcast_in_dim] -> r752 */
    for (long i15185 = 0; i15185 < 6; ++i15185) {
        long t15187 = i15185;
        long c151860 = t15187 / 6; t15187 %= 6;
        long c151861 = t15187 / 6; t15187 %= 6;
        long c151862 = t15187;
        r752[i15185] = r745[c151862 * 1];
    }
    /* sub [sub] -> r753 */
    for (long i15188 = 0; i15188 < 60; ++i15188) {
        long t15190 = i15188;
        long c151890 = t15190 / 60; t15190 %= 60;
        long c151891 = t15190 / 6; t15190 %= 6;
        long c151892 = t15190;
        r753[i15188] = sub32(r752[c151892 * 1], r744[c151891 * 6 + c151892 * 1]);
    }
    /* convert [convert_element_type] -> r754 */
    for (long i15191 = 0; i15191 < 1; ++i15191) {
        r754[i15191] = (int32_t)r49[0];
    }
    /* max [max] -> r755 */
    for (long i15192 = 0; i15192 < 60; ++i15192) {
        r755[i15192] = max32(r754[0], r753[i15192]);
    }
    /* convert [convert_element_type] -> r756 */
    for (long i15193 = 0; i15193 < 1; ++i15193) {
        r756[i15193] = (int32_t)r50[0];
    }
    /* min [min] -> r757 */
    for (long i15194 = 0; i15194 < 60; ++i15194) {
        r757[i15194] = min32(r756[0], r755[i15194]);
    }
    /* abs [abs] -> r758 */
    for (long i15195 = 0; i15195 < 60; ++i15195) {
        r758[i15195] = abs32(r751[i15195]);
    }
    /* reduce_max [reduce_max] -> r759 */
    for (long i15196 = 0; i15196 < 10; ++i15196) {
        r759[i15196] = (-2147483647 - 1);
    }
    for (long i15197 = 0; i15197 < 60; ++i15197) {
        long t15199 = i15197;
        long c151980 = t15199 / 60; t15199 %= 60;
        long c151981 = t15199 / 6; t15199 %= 6;
        long c151982 = t15199;
        r759[c151980 * 10 + c151981 * 1] = max32(r759[c151980 * 10 + c151981 * 1], r758[i15197]);
    }
    /* sub [sub] -> r760 */
    for (long i15200 = 0; i15200 < 10; ++i15200) {
        r760[i15200] = sub32(r759[i15200], r62[0]);
    }
    /* loop [scan] -> r782 */
    memcpy(r761, r751, sizeof(int32_t) * 60);
    memcpy(r762, r62, sizeof(int32_t) * 1);
    memcpy(r763, r40, sizeof(int32_t) * 1);
    memcpy(r764, r760, sizeof(int32_t) * 10);
    memcpy(r765, r759, sizeof(int32_t) * 10);
    for (long t15201 = 0; t15201 < 12; ++t15201) {
        /* add [add] -> r766 */
        for (long i16202 = 0; i16202 < 1; ++i16202) {
            r766[i16202] = add32(r763[0], r30[0]);
        }
        /* add [add] -> r767 */
        for (long i16203 = 0; i16203 < 10; ++i16203) {
            r767[i16203] = add32(r764[i16203], r765[i16203]);
        }
        /* shra [shift_right_arithmetic] -> r768 */
        for (long i16204 = 0; i16204 < 10; ++i16204) {
            r768[i16204] = asr32(r767[i16204], 1);
        }
        /* broadcast [broadcast_in_dim] -> r769 */
        for (long i16205 = 0; i16205 < 10; ++i16205) {
            long t16207 = i16205;
            long c162060 = t16207 / 10; t16207 %= 10;
            long c162061 = t16207 / 1; t16207 %= 1;
            long c162062 = t16207;
            r769[i16205] = r768[c162061 * 1];
        }
        /* sub [sub] -> r770 */
        for (long i16208 = 0; i16208 < 60; ++i16208) {
            long t16210 = i16208;
            long c162090 = t16210 / 60; t16210 %= 60;
            long c162091 = t16210 / 6; t16210 %= 6;
            long c162092 = t16210;
            r770[i16208] = sub32(r761[c162091 * 6 + c162092 * 1], r769[c162091 * 1]);
        }
        /* max [max] -> r771 */
        for (long i16211 = 0; i16211 < 60; ++i16211) {
            r771[i16211] = max32(r770[i16211], r40[0]);
        }
        /* reduce_sum [reduce_sum] -> r772 */
        for (long i16212 = 0; i16212 < 10; ++i16212) {
            r772[i16212] = 0;
        }
        for (long i16213 = 0; i16213 < 60; ++i16213) {
            long t16215 = i16213;
            long c162140 = t16215 / 60; t16215 %= 60;
            long c162141 = t16215 / 6; t16215 %= 6;
            long c162142 = t16215;
            r772[c162140 * 10 + c162141 * 1] = add32(r772[c162140 * 10 + c162141 * 1], r771[i16213]);
        }
        /* neg [neg] -> r773 */
        for (long i16216 = 0; i16216 < 60; ++i16216) {
            r773[i16216] = neg32(r761[i16216]);
        }
        /* broadcast [broadcast_in_dim] -> r774 */
        for (long i16217 = 0; i16217 < 10; ++i16217) {
            long t16219 = i16217;
            long c162180 = t16219 / 10; t16219 %= 10;
            long c162181 = t16219 / 1; t16219 %= 1;
            long c162182 = t16219;
            r774[i16217] = r768[c162181 * 1];
        }
        /* sub [sub] -> r775 */
        for (long i16220 = 0; i16220 < 60; ++i16220) {
            long t16222 = i16220;
            long c162210 = t16222 / 60; t16222 %= 60;
            long c162211 = t16222 / 6; t16222 %= 6;
            long c162212 = t16222;
            r775[i16220] = sub32(r773[c162211 * 6 + c162212 * 1], r774[c162211 * 1]);
        }
        /* max [max] -> r776 */
        for (long i16223 = 0; i16223 < 60; ++i16223) {
            r776[i16223] = max32(r775[i16223], r40[0]);
        }
        /* reduce_sum [reduce_sum] -> r777 */
        for (long i16224 = 0; i16224 < 10; ++i16224) {
            r777[i16224] = 0;
        }
        for (long i16225 = 0; i16225 < 60; ++i16225) {
            long t16227 = i16225;
            long c162260 = t16227 / 60; t16227 %= 60;
            long c162261 = t16227 / 6; t16227 %= 6;
            long c162262 = t16227;
            r777[c162260 * 10 + c162261 * 1] = add32(r777[c162260 * 10 + c162261 * 1], r776[i16225]);
        }
        /* add [add] -> r778 */
        for (long i16228 = 0; i16228 < 10; ++i16228) {
            r778[i16228] = add32(r772[i16228], r777[i16228]);
        }
        /* gt [gt] -> r779 */
        for (long i16229 = 0; i16229 < 10; ++i16229) {
            r779[i16229] = r778[i16229] > r762[0] ? 1 : 0;
        }
        /* select_n [select_n] -> r780 */
        for (long i16230 = 0; i16230 < 10; ++i16230) {
            r780[i16230] = r779[i16230] == 0 ? r764[i16230] : (r768[i16230]);
        }
        /* select_n [select_n] -> r781 */
        for (long i16231 = 0; i16231 < 10; ++i16231) {
            r781[i16231] = r779[i16231] == 0 ? r768[i16231] : (r765[i16231]);
        }
        memcpy(r763, r766, sizeof(int32_t) * 1);
        memcpy(r764, r780, sizeof(int32_t) * 10);
        memcpy(r765, r781, sizeof(int32_t) * 10);
    }
    memcpy(r782, r763, sizeof(int32_t) * 1);
    memcpy(r783, r764, sizeof(int32_t) * 10);
    memcpy(r784, r765, sizeof(int32_t) * 10);
    /* abs [abs] -> r785 */
    for (long i16232 = 0; i16232 < 60; ++i16232) {
        r785[i16232] = abs32(r757[i16232]);
    }
    /* reduce_max [reduce_max] -> r786 */
    for (long i16233 = 0; i16233 < 10; ++i16233) {
        r786[i16233] = (-2147483647 - 1);
    }
    for (long i16234 = 0; i16234 < 60; ++i16234) {
        long t16236 = i16234;
        long c162350 = t16236 / 60; t16236 %= 60;
        long c162351 = t16236 / 6; t16236 %= 6;
        long c162352 = t16236;
        r786[c162350 * 10 + c162351 * 1] = max32(r786[c162350 * 10 + c162351 * 1], r785[i16234]);
    }
    /* sub [sub] -> r787 */
    for (long i16237 = 0; i16237 < 10; ++i16237) {
        r787[i16237] = sub32(r786[i16237], r62[0]);
    }
    /* loop [scan] -> r809 */
    memcpy(r788, r757, sizeof(int32_t) * 60);
    memcpy(r789, r62, sizeof(int32_t) * 1);
    memcpy(r790, r40, sizeof(int32_t) * 1);
    memcpy(r791, r787, sizeof(int32_t) * 10);
    memcpy(r792, r786, sizeof(int32_t) * 10);
    for (long t16238 = 0; t16238 < 12; ++t16238) {
        /* add [add] -> r793 */
        for (long i17239 = 0; i17239 < 1; ++i17239) {
            r793[i17239] = add32(r790[0], r30[0]);
        }
        /* add [add] -> r794 */
        for (long i17240 = 0; i17240 < 10; ++i17240) {
            r794[i17240] = add32(r791[i17240], r792[i17240]);
        }
        /* shra [shift_right_arithmetic] -> r795 */
        for (long i17241 = 0; i17241 < 10; ++i17241) {
            r795[i17241] = asr32(r794[i17241], 1);
        }
        /* broadcast [broadcast_in_dim] -> r796 */
        for (long i17242 = 0; i17242 < 10; ++i17242) {
            long t17244 = i17242;
            long c172430 = t17244 / 10; t17244 %= 10;
            long c172431 = t17244 / 1; t17244 %= 1;
            long c172432 = t17244;
            r796[i17242] = r795[c172431 * 1];
        }
        /* sub [sub] -> r797 */
        for (long i17245 = 0; i17245 < 60; ++i17245) {
            long t17247 = i17245;
            long c172460 = t17247 / 60; t17247 %= 60;
            long c172461 = t17247 / 6; t17247 %= 6;
            long c172462 = t17247;
            r797[i17245] = sub32(r788[c172461 * 6 + c172462 * 1], r796[c172461 * 1]);
        }
        /* max [max] -> r798 */
        for (long i17248 = 0; i17248 < 60; ++i17248) {
            r798[i17248] = max32(r797[i17248], r40[0]);
        }
        /* reduce_sum [reduce_sum] -> r799 */
        for (long i17249 = 0; i17249 < 10; ++i17249) {
            r799[i17249] = 0;
        }
        for (long i17250 = 0; i17250 < 60; ++i17250) {
            long t17252 = i17250;
            long c172510 = t17252 / 60; t17252 %= 60;
            long c172511 = t17252 / 6; t17252 %= 6;
            long c172512 = t17252;
            r799[c172510 * 10 + c172511 * 1] = add32(r799[c172510 * 10 + c172511 * 1], r798[i17250]);
        }
        /* neg [neg] -> r800 */
        for (long i17253 = 0; i17253 < 60; ++i17253) {
            r800[i17253] = neg32(r788[i17253]);
        }
        /* broadcast [broadcast_in_dim] -> r801 */
        for (long i17254 = 0; i17254 < 10; ++i17254) {
            long t17256 = i17254;
            long c172550 = t17256 / 10; t17256 %= 10;
            long c172551 = t17256 / 1; t17256 %= 1;
            long c172552 = t17256;
            r801[i17254] = r795[c172551 * 1];
        }
        /* sub [sub] -> r802 */
        for (long i17257 = 0; i17257 < 60; ++i17257) {
            long t17259 = i17257;
            long c172580 = t17259 / 60; t17259 %= 60;
            long c172581 = t17259 / 6; t17259 %= 6;
            long c172582 = t17259;
            r802[i17257] = sub32(r800[c172581 * 6 + c172582 * 1], r801[c172581 * 1]);
        }
        /* max [max] -> r803 */
        for (long i17260 = 0; i17260 < 60; ++i17260) {
            r803[i17260] = max32(r802[i17260], r40[0]);
        }
        /* reduce_sum [reduce_sum] -> r804 */
        for (long i17261 = 0; i17261 < 10; ++i17261) {
            r804[i17261] = 0;
        }
        for (long i17262 = 0; i17262 < 60; ++i17262) {
            long t17264 = i17262;
            long c172630 = t17264 / 60; t17264 %= 60;
            long c172631 = t17264 / 6; t17264 %= 6;
            long c172632 = t17264;
            r804[c172630 * 10 + c172631 * 1] = add32(r804[c172630 * 10 + c172631 * 1], r803[i17262]);
        }
        /* add [add] -> r805 */
        for (long i17265 = 0; i17265 < 10; ++i17265) {
            r805[i17265] = add32(r799[i17265], r804[i17265]);
        }
        /* gt [gt] -> r806 */
        for (long i17266 = 0; i17266 < 10; ++i17266) {
            r806[i17266] = r805[i17266] > r789[0] ? 1 : 0;
        }
        /* select_n [select_n] -> r807 */
        for (long i17267 = 0; i17267 < 10; ++i17267) {
            r807[i17267] = r806[i17267] == 0 ? r791[i17267] : (r795[i17267]);
        }
        /* select_n [select_n] -> r808 */
        for (long i17268 = 0; i17268 < 10; ++i17268) {
            r808[i17268] = r806[i17268] == 0 ? r795[i17268] : (r792[i17268]);
        }
        memcpy(r790, r793, sizeof(int32_t) * 1);
        memcpy(r791, r807, sizeof(int32_t) * 10);
        memcpy(r792, r808, sizeof(int32_t) * 10);
    }
    memcpy(r809, r790, sizeof(int32_t) * 1);
    memcpy(r810, r791, sizeof(int32_t) * 10);
    memcpy(r811, r792, sizeof(int32_t) * 10);
    /* sub [sub] -> r812 */
    for (long i17269 = 0; i17269 < 10; ++i17269) {
        r812[i17269] = sub32(r784[i17269], r811[i17269]);
    }
    /* shra [shift_right_arithmetic] -> r813 */
    for (long i17270 = 0; i17270 < 10; ++i17270) {
        r813[i17270] = asr32(r812[i17270], 1);
    }
    /* convert [convert_element_type] -> r814 */
    for (long i17271 = 0; i17271 < 1; ++i17271) {
        r814[i17271] = (int32_t)r222[0];
    }
    /* max [max] -> r815 */
    for (long i17272 = 0; i17272 < 10; ++i17272) {
        r815[i17272] = max32(r814[0], r813[i17272]);
    }
    /* convert [convert_element_type] -> r816 */
    for (long i17273 = 0; i17273 < 1; ++i17273) {
        r816[i17273] = (int32_t)r223[0];
    }
    /* min [min] -> r817 */
    for (long i17274 = 0; i17274 < 10; ++i17274) {
        r817[i17274] = min32(r816[0], r815[i17274]);
    }
    /* sub [sub] -> r818 */
    for (long i17275 = 0; i17275 < 1; ++i17275) {
        r818[i17275] = sub32(r624[i17275], r725[i17275]);
    }
    /* add [add] -> r819 */
    for (long i17276 = 0; i17276 < 1; ++i17276) {
        r819[i17276] = add32(r818[i17276], r30[0]);
    }
    /* max [max] -> r820 */
    for (long i17277 = 0; i17277 < 1; ++i17277) {
        r820[i17277] = max32(r819[i17277], r40[0]);
    }
    /* shra [shift_right_arithmetic] -> r821 */
    for (long i17278 = 0; i17278 < 1; ++i17278) {
        r821[i17278] = asr32(r820[i17278], 1);
    }
    /* concat [concatenate] -> r822 */
    for (long i17279 = 0; i17279 < 15; ++i17279) {
        long t17281 = i17279;
        long c172800 = t17281 / 15; t17281 %= 15;
        long c172801 = t17281;
        r822[c172800 * 25 + (c172801 + 0) * 1] = r4[i17279];
    }
    for (long i17282 = 0; i17282 < 10; ++i17282) {
        long t17284 = i17282;
        long c172830 = t17284 / 10; t17284 %= 10;
        long c172831 = t17284;
        r822[c172830 * 25 + (c172831 + 15) * 1] = r817[i17282];
    }
    /* shl [shift_left] -> r823 */
    for (long i17285 = 0; i17285 < 25; ++i17285) {
        r823[i17285] = shl32(r822[i17285], 1);
    }
    /* mov [device_put] -> r824 */
    memcpy(r824, r18, sizeof(int32_t) * 80);
    /* rev [rev] -> r825 */
    for (long i17286 = 0; i17286 < 80; ++i17286) {
        long t17288 = i17286;
        long c172870 = t17288 / 16; t17288 %= 16;
        long c172871 = t17288;
        r825[i17286] = r824[c172870 * 16 + (16 - 1 - c172871) * 1];
    }
    /* reshape [reshape] -> r826 */
    memcpy(r826, r825, sizeof(int32_t) * 80);
    /* iota [iota] -> r827 */
    for (long i17289 = 0; i17289 < 10; ++i17289) {
        long t17291 = i17289;
        long c172900 = t17291;
        r827[i17289] = (int32_t)c172900;
    }
    /* broadcast [broadcast_in_dim] -> r828 */
    for (long i17292 = 0; i17292 < 10; ++i17292) {
        long t17294 = i17292;
        long c172930 = t17294 / 1; t17294 %= 1;
        long c172931 = t17294;
        r828[i17292] = r827[c172930 * 1];
    }
    /* iota [iota] -> r829 */
    for (long i17295 = 0; i17295 < 16; ++i17295) {
        long t17297 = i17295;
        long c172960 = t17297;
        r829[i17295] = (int32_t)c172960;
    }
    /* broadcast [broadcast_in_dim] -> r830 */
    for (long i17298 = 0; i17298 < 16; ++i17298) {
        long t17300 = i17298;
        long c172990 = t17300 / 16; t17300 %= 16;
        long c172991 = t17300;
        r830[i17298] = r829[c172991 * 1];
    }
    /* add [add] -> r831 */
    for (long i17301 = 0; i17301 < 160; ++i17301) {
        long t17303 = i17301;
        long c173020 = t17303 / 16; t17303 %= 16;
        long c173021 = t17303;
        r831[i17301] = add32(r828[c173020 * 1], r830[c173021 * 1]);
    }
    /* lt [lt] -> r832 */
    for (long i17304 = 0; i17304 < 160; ++i17304) {
        r832[i17304] = r831[i17304] < r40[0] ? 1 : 0;
    }
    /* add [add] -> r834 */
    for (long i17305 = 0; i17305 < 160; ++i17305) {
        r834[i17305] = add32(r831[i17305], r833[0]);
    }
    /* select_n [select_n] -> r835 */
    for (long i17306 = 0; i17306 < 160; ++i17306) {
        r835[i17306] = r832[i17306] == 0 ? r831[i17306] : (r834[i17306]);
    }
    /* broadcast [broadcast_in_dim] -> r836 */
    for (long i17307 = 0; i17307 < 160; ++i17307) {
        long t17309 = i17307;
        long c173080 = t17309 / 16; t17309 %= 16;
        long c173081 = t17309 / 1; t17309 %= 1;
        long c173082 = t17309;
        r836[i17307] = r835[c173080 * 16 + c173081 * 1];
    }
    /* gather [gather] -> r837 */
    for (long i17310 = 0; i17310 < 160; ++i17310) {
        long t17312 = i17310;
        long c173110 = t17312 / 160; t17312 %= 160;
        long c173111 = t17312 / 16; t17312 %= 16;
        long c173112 = t17312;
        long row17313 = c173111 * 16 + c173112 * 1;
        long s17314 = clamp_start((long)r836[row17313 + 0], 25, 1);
        r837[i17310] = r823[c173110 * 25 + s17314 * 1];
    }
    /* broadcast [broadcast_in_dim] -> r838 */
    for (long i17315 = 0; i17315 < 160; ++i17315) {
        long t17317 = i17315;
        long c173160 = t17317 / 160; t17317 %= 160;
        long c173161 = t17317 / 160; t17317 %= 160;
        long c173162 = t17317 / 16; t17317 %= 16;
        long c173163 = t17317;
        r838[i17315] = r837[c173162 * 16 + c173163 * 1];
    }
    /* add [add] -> r839 */
    for (long i17318 = 0; i17318 < 800; ++i17318) {
        long t17320 = i17318;
        long c173190 = t17320 / 160; t17320 %= 160;
        long c173191 = t17320 / 160; t17320 %= 160;
        long c173192 = t17320 / 16; t17320 %= 16;
        long c173193 = t17320;
        r839[i17318] = add32(r826[c173190 * 16 + c173193 * 1], r838[c173192 * 16 + c173193 * 1]);
    }
    /* convert [convert_element_type] -> r840 */
    for (long i17321 = 0; i17321 < 1; ++i17321) {
        r840[i17321] = (int32_t)r49[0];
    }
    /* max [max] -> r841 */
    for (long i17322 = 0; i17322 < 800; ++i17322) {
        r841[i17322] = max32(r840[0], r839[i17322]);
    }
    /* convert [convert_element_type] -> r842 */
    for (long i17323 = 0; i17323 < 1; ++i17323) {
        r842[i17323] = (int32_t)r50[0];
    }
    /* min [min] -> r843 */
    for (long i17324 = 0; i17324 < 800; ++i17324) {
        r843[i17324] = min32(r842[0], r841[i17324]);
    }
    /* sub [sub] -> r844 */
    for (long i17325 = 0; i17325 < 800; ++i17325) {
        long t17327 = i17325;
        long c173260 = t17327 / 160; t17327 %= 160;
        long c173261 = t17327 / 160; t17327 %= 160;
        long c173262 = t17327 / 16; t17327 %= 16;
        long c173263 = t17327;
        r844[i17325] = sub32(r826[c173260 * 16 + c173263 * 1], r838[c173262 * 16 + c173263 * 1]);
    }
    /* convert [convert_element_type] -> r845 */
    for (long i17328 = 0; i17328 < 1; ++i17328) {
        r845[i17328] = (int32_t)r49[0];
    }
    /* max [max] -> r846 */
    for (long i17329 = 0; i17329 < 800; ++i17329) {
        r846[i17329] = max32(r845[0], r844[i17329]);
    }
    /* convert [convert_element_type] -> r847 */
    for (long i17330 = 0; i17330 < 1; ++i17330) {
        r847[i17330] = (int32_t)r50[0];
    }
    /* min [min] -> r848 */
    for (long i17331 = 0; i17331 < 800; ++i17331) {
        r848[i17331] = min32(r847[0], r846[i17331]);
    }
    /* abs [abs] -> r849 */
    for (long i17332 = 0; i17332 < 800; ++i17332) {
        r849[i17332] = abs32(r843[i17332]);
    }
    /* reduce_max [reduce_max] -> r850 */
    for (long i17333 = 0; i17333 < 50; ++i17333) {
        r850[i17333] = (-2147483647 - 1);
    }
    for (long i17334 = 0; i17334 < 800; ++i17334) {
        long t17336 = i17334;
        long c173350 = t17336 / 160; t17336 %= 160;
        long c173351 = t17336 / 160; t17336 %= 160;
        long c173352 = t17336 / 16; t17336 %= 16;
        long c173353 = t17336;
        r850[c173350 * 10 + c173351 * 10 + c173352 * 1] = max32(r850[c173350 * 10 + c173351 * 10 + c173352 * 1], r849[i17334]);
    }
    /* sub [sub] -> r851 */
    for (long i17337 = 0; i17337 < 50; ++i17337) {
        r851[i17337] = sub32(r850[i17337], r62[0]);
    }
    /* loop [scan] -> r873 */
    memcpy(r852, r843, sizeof(int32_t) * 800);
    memcpy(r853, r62, sizeof(int32_t) * 1);
    memcpy(r854, r40, sizeof(int32_t) * 1);
    memcpy(r855, r851, sizeof(int32_t) * 50);
    memcpy(r856, r850, sizeof(int32_t) * 50);
    for (long t17338 = 0; t17338 < 12; ++t17338) {
        /* add [add] -> r857 */
        for (long i18339 = 0; i18339 < 1; ++i18339) {
            r857[i18339] = add32(r854[0], r30[0]);
        }
        /* add [add] -> r858 */
        for (long i18340 = 0; i18340 < 50; ++i18340) {
            r858[i18340] = add32(r855[i18340], r856[i18340]);
        }
        /* shra [shift_right_arithmetic] -> r859 */
        for (long i18341 = 0; i18341 < 50; ++i18341) {
            r859[i18341] = asr32(r858[i18341], 1);
        }
        /* broadcast [broadcast_in_dim] -> r860 */
        for (long i18342 = 0; i18342 < 50; ++i18342) {
            long t18344 = i18342;
            long c183430 = t18344 / 10; t18344 %= 10;
            long c183431 = t18344 / 10; t18344 %= 10;
            long c183432 = t18344 / 1; t18344 %= 1;
            long c183433 = t18344;
            r860[i18342] = r859[c183430 * 10 + c183432 * 1];
        }
        /* sub [sub] -> r861 */
        for (long i18345 = 0; i18345 < 800; ++i18345) {
            long t18347 = i18345;
            long c183460 = t18347 / 160; t18347 %= 160;
            long c183461 = t18347 / 160; t18347 %= 160;
            long c183462 = t18347 / 16; t18347 %= 16;
            long c183463 = t18347;
            r861[i18345] = sub32(r852[c183460 * 160 + c183462 * 16 + c183463 * 1], r860[c183460 * 10 + c183462 * 1]);
        }
        /* max [max] -> r862 */
        for (long i18348 = 0; i18348 < 800; ++i18348) {
            r862[i18348] = max32(r861[i18348], r40[0]);
        }
        /* reduce_sum [reduce_sum] -> r863 */
        for (long i18349 = 0; i18349 < 50; ++i18349) {
            r863[i18349] = 0;
        }
        for (long i18350 = 0; i18350 < 800; ++i18350) {
            long t18352 = i18350;
            long c183510 = t18352 / 160; t18352 %= 160;
            long c183511 = t18352 / 160; t18352 %= 160;
            long c183512 = t18352 / 16; t18352 %= 16;
            long c183513 = t18352;
            r863[c183510 * 10 + c183511 * 10 + c183512 * 1] = add32(r863[c183510 * 10 + c183511 * 10 + c183512 * 1], r862[i18350]);
        }
        /* neg [neg] -> r864 */
        for (long i18353 = 0; i18353 < 800; ++i18353) {
            r864[i18353] = neg32(r852[i18353]);
        }
        /* broadcast [broadcast_in_dim] -> r865 */
        for (long i18354 = 0; i18354 < 50; ++i18354) {
            long t18356 = i18354;
            long c183550 = t18356 / 10; t18356 %= 10;
            long c183551 = t18356 / 10; t18356 %= 10;
            long c183552 = t18356 / 1; t18356 %= 1;
            long c183553 = t18356;
            r865[i18354] = r859[c183550 * 10 + c183552 * 1];
        }
        /* sub [sub] -> r866 */
        for (long i18357 = 0; i18357 < 800; ++i18357) {
            long t18359 = i18357;
            long c183580 = t18359 / 160; t18359 %= 160;
            long c183581 = t18359 / 160; t18359 %= 160;
            long c183582 = t18359 / 16; t18359 %= 16;
            long c183583 = t18359;
            r866[i18357] = sub32(r864[c183580 * 160 + c183582 * 16 + c183583 * 1], r865[c183580 * 10 + c183582 * 1]);
        }
        /* max [max] -> r867 */
        for (long i18360 = 0; i18360 < 800; ++i18360) {
            r867[i18360] = max32(r866[i18360], r40[0]);
        }
        /* reduce_sum [reduce_sum] -> r868 */
        for (long i18361 = 0; i18361 < 50; ++i18361) {
            r868[i18361] = 0;
        }
        for (long i18362 = 0; i18362 < 800; ++i18362) {
            long t18364 = i18362;
            long c183630 = t18364 / 160; t18364 %= 160;
            long c183631 = t18364 / 160; t18364 %= 160;
            long c183632 = t18364 / 16; t18364 %= 16;
            long c183633 = t18364;
            r868[c183630 * 10 + c183631 * 10 + c183632 * 1] = add32(r868[c183630 * 10 + c183631 * 10 + c183632 * 1], r867[i18362]);
        }
        /* add [add] -> r869 */
        for (long i18365 = 0; i18365 < 50; ++i18365) {
            r869[i18365] = add32(r863[i18365], r868[i18365]);
        }
        /* gt [gt] -> r870 */
        for (long i18366 = 0; i18366 < 50; ++i18366) {
            r870[i18366] = r869[i18366] > r853[0] ? 1 : 0;
        }
        /* select_n [select_n] -> r871 */
        for (long i18367 = 0; i18367 < 50; ++i18367) {
            r871[i18367] = r870[i18367] == 0 ? r855[i18367] : (r859[i18367]);
        }
        /* select_n [select_n] -> r872 */
        for (long i18368 = 0; i18368 < 50; ++i18368) {
            r872[i18368] = r870[i18368] == 0 ? r859[i18368] : (r856[i18368]);
        }
        memcpy(r854, r857, sizeof(int32_t) * 1);
        memcpy(r855, r871, sizeof(int32_t) * 50);
        memcpy(r856, r872, sizeof(int32_t) * 50);
    }
    memcpy(r873, r854, sizeof(int32_t) * 1);
    memcpy(r874, r855, sizeof(int32_t) * 50);
    memcpy(r875, r856, sizeof(int32_t) * 50);
    /* abs [abs] -> r876 */
    for (long i18369 = 0; i18369 < 800; ++i18369) {
        r876[i18369] = abs32(r848[i18369]);
    }
    /* reduce_max [reduce_max] -> r877 */
    for (long i18370 = 0; i18370 < 50; ++i18370) {
        r877[i18370] = (-2147483647 - 1);
    }
    for (long i18371 = 0; i18371 < 800; ++i18371) {
        long t18373 = i18371;
        long c183720 = t18373 / 160; t18373 %= 160;
        long c183721 = t18373 / 160; t18373 %= 160;
        long c183722 = t18373 / 16; t18373 %= 16;
        long c183723 = t18373;
        r877[c183720 * 10 + c183721 * 10 + c183722 * 1] = max32(r877[c183720 * 10 + c183721 * 10 + c183722 * 1], r876[i18371]);
    }
    /* sub [sub] -> r878 */
    for (long i18374 = 0; i18374 < 50; ++i18374) {
        r878[i18374] = sub32(r877[i18374], r62[0]);
    }
    /* loop [scan] -> r900 */
    memcpy(r879, r848, sizeof(int32_t) * 800);
    memcpy(r880, r62, sizeof(int32_t) * 1);
    memcpy(r881, r40, sizeof(int32_t) * 1);
    memcpy(r882, r878, sizeof(int32_t) * 50);
    memcpy(r883, r877, sizeof(int32_t) * 50);
    for (long t18375 = 0; t18375 < 12; ++t18375) {
        /* add [add] -> r884 */
        for (long i19376 = 0; i19376 < 1; ++i19376) {
            r884[i19376] = add32(r881[0], r30[0]);
        }
        /* add [add] -> r885 */
        for (long i19377 = 0; i19377 < 50; ++i19377) {
            r885[i19377] = add32(r882[i19377], r883[i19377]);
        }
        /* shra [shift_right_arithmetic] -> r886 */
        for (long i19378 = 0; i19378 < 50; ++i19378) {
            r886[i19378] = asr32(r885[i19378], 1);
        }
        /* broadcast [broadcast_in_dim] -> r887 */
        for (long i19379 = 0; i19379 < 50; ++i19379) {
            long t19381 = i19379;
            long c193800 = t19381 / 10; t19381 %= 10;
            long c193801 = t19381 / 10; t19381 %= 10;
            long c193802 = t19381 / 1; t19381 %= 1;
            long c193803 = t19381;
            r887[i19379] = r886[c193800 * 10 + c193802 * 1];
        }
        /* sub [sub] -> r888 */
        for (long i19382 = 0; i19382 < 800; ++i19382) {
            long t19384 = i19382;
            long c193830 = t19384 / 160; t19384 %= 160;
            long c193831 = t19384 / 160; t19384 %= 160;
            long c193832 = t19384 / 16; t19384 %= 16;
            long c193833 = t19384;
            r888[i19382] = sub32(r879[c193830 * 160 + c193832 * 16 + c193833 * 1], r887[c193830 * 10 + c193832 * 1]);
        }
        /* max [max] -> r889 */
        for (long i19385 = 0; i19385 < 800; ++i19385) {
            r889[i19385] = max32(r888[i19385], r40[0]);
        }
        /* reduce_sum [reduce_sum] -> r890 */
        for (long i19386 = 0; i19386 < 50; ++i19386) {
            r890[i19386] = 0;
        }
        for (long i19387 = 0; i19387 < 800; ++i19387) {
            long t19389 = i19387;
            long c193880 = t19389 / 160; t19389 %= 160;
            long c193881 = t19389 / 160; t19389 %= 160;
            long c193882 = t19389 / 16; t19389 %= 16;
            long c193883 = t19389;
            r890[c193880 * 10 + c193881 * 10 + c193882 * 1] = add32(r890[c193880 * 10 + c193881 * 10 + c193882 * 1], r889[i19387]);
        }
        /* neg [neg] -> r891 */
        for (long i19390 = 0; i19390 < 800; ++i19390) {
            r891[i19390] = neg32(r879[i19390]);
        }
        /* broadcast [broadcast_in_dim] -> r892 */
        for (long i19391 = 0; i19391 < 50; ++i19391) {
            long t19393 = i19391;
            long c193920 = t19393 / 10; t19393 %= 10;
            long c193921 = t19393 / 10; t19393 %= 10;
            long c193922 = t19393 / 1; t19393 %= 1;
            long c193923 = t19393;
            r892[i19391] = r886[c193920 * 10 + c193922 * 1];
        }
        /* sub [sub] -> r893 */
        for (long i19394 = 0; i19394 < 800; ++i19394) {
            long t19396 = i19394;
            long c193950 = t19396 / 160; t19396 %= 160;
            long c193951 = t19396 / 160; t19396 %= 160;
            long c193952 = t19396 / 16; t19396 %= 16;
            long c193953 = t19396;
            r893[i19394] = sub32(r891[c193950 * 160 + c193952 * 16 + c193953 * 1], r892[c193950 * 10 + c193952 * 1]);
        }
        /* max [max] -> r894 */
        for (long i19397 = 0; i19397 < 800; ++i19397) {
            r894[i19397] = max32(r893[i19397], r40[0]);
        }
        /* reduce_sum [reduce_sum] -> r895 */
        for (long i19398 = 0; i19398 < 50; ++i19398) {
            r895[i19398] = 0;
        }
        for (long i19399 = 0; i19399 < 800; ++i19399) {
            long t19401 = i19399;
            long c194000 = t19401 / 160; t19401 %= 160;
            long c194001 = t19401 / 160; t19401 %= 160;
            long c194002 = t19401 / 16; t19401 %= 16;
            long c194003 = t19401;
            r895[c194000 * 10 + c194001 * 10 + c194002 * 1] = add32(r895[c194000 * 10 + c194001 * 10 + c194002 * 1], r894[i19399]);
        }
        /* add [add] -> r896 */
        for (long i19402 = 0; i19402 < 50; ++i19402) {
            r896[i19402] = add32(r890[i19402], r895[i19402]);
        }
        /* gt [gt] -> r897 */
        for (long i19403 = 0; i19403 < 50; ++i19403) {
            r897[i19403] = r896[i19403] > r880[0] ? 1 : 0;
        }
        /* select_n [select_n] -> r898 */
        for (long i19404 = 0; i19404 < 50; ++i19404) {
            r898[i19404] = r897[i19404] == 0 ? r882[i19404] : (r886[i19404]);
        }
        /* select_n [select_n] -> r899 */
        for (long i19405 = 0; i19405 < 50; ++i19405) {
            r899[i19405] = r897[i19405] == 0 ? r886[i19405] : (r883[i19405]);
        }
        memcpy(r881, r884, sizeof(int32_t) * 1);
        memcpy(r882, r898, sizeof(int32_t) * 50);
        memcpy(r883, r899, sizeof(int32_t) * 50);
    }
    memcpy(r900, r881, sizeof(int32_t) * 1);
    memcpy(r901, r882, sizeof(int32_t) * 50);
    memcpy(r902, r883, sizeof(int32_t) * 50);
    /* sub [sub] -> r903 */
    for (long i19406 = 0; i19406 < 50; ++i19406) {
        r903[i19406] = sub32(r875[i19406], r902[i19406]);
    }
    /* transpose [transpose] -> r904 */
    for (long i19407 = 0; i19407 < 50; ++i19407) {
        long t19409 = i19407;
        long c194080 = t19409 / 50; t19409 %= 50;
        long c194081 = t19409 / 10; t19409 %= 10;
        long c194082 = t19409;
        r904[i19407] = r903[c194080 * 10 + c194081 * 10 + c194082 * 1];
    }
    /* broadcast [broadcast_in_dim] -> r905 */
    for (long i19410 = 0; i19410 < 1; ++i19410) {
        long t19412 = i19410;
        long c194110 = t19412 / 1; t19412 %= 1;
        long c194111 = t19412;
        r905[i19410] = r821[0];
    }
    /* max [max] -> r906 */
    for (long i19413 = 0; i19413 < 50; ++i19413) {
        r906[i19413] = max32(r904[i19413], r40[0]);
    }
    /* iota [iota] -> r907 */
    for (long i19414 = 0; i19414 < 50; ++i19414) {
        long t19416 = i19414;
        long c194150 = t19416 / 50; t19416 %= 50;
        long c194151 = t19416 / 10; t19416 %= 10;
        long c194152 = t19416;
        r907[i19414] = (int32_t)c194152;
    }
    /* broadcast [broadcast_in_dim] -> r908 */
    for (long i19417 = 0; i19417 < 1; ++i19417) {
        long t19419 = i19417;
        long c194180 = t19419 / 1; t19419 %= 1;
        long c194181 = t19419 / 1; t19419 %= 1;
        long c194182 = t19419;
        r908[i19417] = r905[0];
    }
    /* lt [lt] -> r909 */
    for (long i19420 = 0; i19420 < 50; ++i19420) {
        long t19422 = i19420;
        long c194210 = t19422 / 50; t19422 %= 50;
        long c194211 = t19422 / 10; t19422 %= 10;
        long c194212 = t19422;
        r909[i19420] = r907[c194211 * 10 + c194212 * 1] < r908[0] ? 1 : 0;
    }
    /* convert [convert_element_type] -> r910 */
    for (long i19423 = 0; i19423 < 1; ++i19423) {
        r910[i19423] = (int32_t)r40[0];
    }
    /* broadcast [broadcast_in_dim] -> r911 */
    for (long i19424 = 0; i19424 < 50; ++i19424) {
        long t19426 = i19424;
        long c194250 = t19426 / 50; t19426 %= 50;
        long c194251 = t19426 / 10; t19426 %= 10;
        long c194252 = t19426;
        r911[i19424] = r910[0];
    }
    /* select_n [select_n] -> r912 */
    for (long i19427 = 0; i19427 < 50; ++i19427) {
        r912[i19427] = r909[i19427] == 0 ? r911[i19427] : (r906[i19427]);
    }
    /* reduce_sum [reduce_sum] -> r913 */
    for (long i19428 = 0; i19428 < 5; ++i19428) {
        r913[i19428] = 0;
    }
    for (long i19429 = 0; i19429 < 50; ++i19429) {
        long t19431 = i19429;
        long c194300 = t19431 / 50; t19431 %= 50;
        long c194301 = t19431 / 10; t19431 %= 10;
        long c194302 = t19431;
        r913[c194300 * 5 + c194301 * 1] = add32(r913[c194300 * 5 + c194301 * 1], r912[i19429]);
    }
    /* shl [shift_left] -> r915 */
    for (long i19432 = 0; i19432 < 5; ++i19432) {
        r915[i19432] = shl32(r913[i19432], 4);
    }
    /* lt [lt] -> r916 */
    for (long i19433 = 0; i19433 < 1; ++i19433) {
        r916[i19433] = r821[i19433] < r40[0] ? 1 : 0;
    }
    /* add [add] -> r917 */
    for (long i19434 = 0; i19434 < 1; ++i19434) {
        r917[i19434] = add32(r821[i19434], r833[0]);
    }
    /* select_n [select_n] -> r918 */
    for (long i19435 = 0; i19435 < 1; ++i19435) {
        r918[i19435] = r916[i19435] == 0 ? r821[i19435] : (r917[i19435]);
    }
    /* broadcast [broadcast_in_dim] -> r919 */
    for (long i19436 = 0; i19436 < 1; ++i19436) {
        long t19438 = i19436;
        long c194370 = t19438 / 1; t19438 %= 1;
        long c194371 = t19438;
        r919[i19436] = r918[0];
    }
    /* gather [gather] -> r920 */
    for (long i19439 = 0; i19439 < 15; ++i19439) {
        long t19441 = i19439;
        long c194400 = t19441 / 15; t19441 %= 15;
        long c194401 = t19441;
        long row19442 = c194400 * 1;
        long s19443 = clamp_start((long)r919[row19442 + 0], 25, 15);
        r920[i19439] = r822[c194400 * 25 + (s19443 + c194401) * 1];
    }
    /* add [add] -> r921 */
    for (long i19444 = 0; i19444 < 1; ++i19444) {
        r921[i19444] = add32(r10[i19444], r821[i19444]);
    }
    /* and [and] -> r922 */
    for (long i19445 = 0; i19445 < 1; ++i19445) {
        r922[i19445] = r10[i19445] & r30[0];
    }
    /* slice [slice] -> r923 */
    for (long i19446 = 0; i19446 < 15; ++i19446) {
        long t19448 = i19446;
        long c194470 = t19448 / 15; t19448 %= 15;
        long c194471 = t19448;
        r923[i19446] = r822[(0 + c194470 * 1) * 25 + (10 + c194471 * 1) * 1];
    }
    /* shl [shift_left] -> r924 */
    for (long i19449 = 0; i19449 < 15; ++i19449) {
        r924[i19449] = shl32(r923[i19449], 1);
    }
    /* convert [convert_element_type] -> r925 */
    for (long i19450 = 0; i19450 < 1; ++i19450) {
        r925[i19450] = (int32_t)r40[0];
    }
    /* pad [pad] -> r926 */
    for (long i19451 = 0; i19451 < 16; ++i19451) {
        r926[i19451] = r925[0];
    }
    for (long i19452 = 0; i19452 < 15; ++i19452) {
        long t19454 = i19452;
        long c194530 = t19454 / 15; t19454 %= 15;
        long c194531 = t19454;
        long d19455 = 0 + c194530 * 1;
        long d19456 = 0 + c194531 * 1;
        if (d19455 >= 0 && d19455 < 1 && d19456 >= 0 && d19456 < 16) r926[d19455 * 16 + d19456 * 1] = r924[i19452];
    }
    /* iota [iota] -> r927 */
    for (long i19457 = 0; i19457 < 5; ++i19457) {
        long t19459 = i19457;
        long c194580 = t19459;
        r927[i19457] = (int32_t)c194580;
    }
    /* shl [shift_left] -> r928 */
    for (long i19460 = 0; i19460 < 5; ++i19460) {
        r928[i19460] = shl32(r927[i19460], 1);
    }
    /* broadcast [broadcast_in_dim] -> r929 */
    for (long i19461 = 0; i19461 < 5; ++i19461) {
        long t19463 = i19461;
        long c194620 = t19463 / 1; t19463 %= 1;
        long c194621 = t19463;
        r929[i19461] = r928[c194620 * 1];
    }
    /* iota [iota] -> r930 */
    for (long i19464 = 0; i19464 < 6; ++i19464) {
        long t19466 = i19464;
        long c194650 = t19466;
        r930[i19464] = (int32_t)c194650;
    }
    /* broadcast [broadcast_in_dim] -> r931 */
    for (long i19467 = 0; i19467 < 6; ++i19467) {
        long t19469 = i19467;
        long c194680 = t19469 / 6; t19469 %= 6;
        long c194681 = t19469;
        r931[i19467] = r930[c194681 * 1];
    }
    /* add [add] -> r932 */
    for (long i19470 = 0; i19470 < 30; ++i19470) {
        long t19472 = i19470;
        long c194710 = t19472 / 6; t19472 %= 6;
        long c194711 = t19472;
        r932[i19470] = add32(r929[c194710 * 1], r931[c194711 * 1]);
    }
    /* broadcast [broadcast_in_dim] -> r933 */
    for (long i19473 = 0; i19473 < 30; ++i19473) {
        long t19475 = i19473;
        long c194740 = t19475 / 30; t19475 %= 30;
        long c194741 = t19475 / 6; t19475 %= 6;
        long c194742 = t19475;
        r933[i19473] = r932[c194741 * 6 + c194742 * 1];
    }
    /* broadcast [broadcast_in_dim] -> r934 */
    for (long i19476 = 0; i19476 < 1; ++i19476) {
        long t19478 = i19476;
        long c194770 = t19478 / 1; t19478 %= 1;
        long c194771 = t19478 / 1; t19478 %= 1;
        long c194772 = t19478;
        r934[i19476] = r922[0];
    }
    /* add [add] -> r935 */
    for (long i19479 = 0; i19479 < 30; ++i19479) {
        long t19481 = i19479;
        long c194800 = t19481 / 30; t19481 %= 30;
        long c194801 = t19481 / 6; t19481 %= 6;
        long c194802 = t19481;
        r935[i19479] = add32(r934[0], r933[c194801 * 6 + c194802 * 1]);
    }
    /* lt [lt] -> r936 */
    for (long i19482 = 0; i19482 < 30; ++i19482) {
        r936[i19482] = r935[i19482] < r40[0] ? 1 : 0;
    }
    /* add [add] -> r938 */
    for (long i19483 = 0; i19483 < 30; ++i19483) {
        r938[i19483] = add32(r935[i19483], r937[0]);
    }
    /* select_n [select_n] -> r939 */
    for (long i19484 = 0; i19484 < 30; ++i19484) {
        r939[i19484] = r936[i19484] == 0 ? r935[i19484] : (r938[i19484]);
    }
    /* broadcast [broadcast_in_dim] -> r940 */
    for (long i19485 = 0; i19485 < 30; ++i19485) {
        long t19487 = i19485;
        long c194860 = t19487 / 30; t19487 %= 30;
        long c194861 = t19487 / 6; t19487 %= 6;
        long c194862 = t19487 / 1; t19487 %= 1;
        long c194863 = t19487;
        r940[i19485] = r939[c194861 * 6 + c194862 * 1];
    }
    /* gather [gather] -> r941 */
    for (long i19488 = 0; i19488 < 30; ++i19488) {
        long t19490 = i19488;
        long c194890 = t19490 / 30; t19490 %= 30;
        long c194891 = t19490 / 6; t19490 %= 6;
        long c194892 = t19490;
        long row19491 = c194890 * 30 + c194891 * 6 + c194892 * 1;
        long s19492 = clamp_start((long)r940[row19491 + 0], 16, 1);
        r941[i19488] = r926[c194890 * 16 + s19492 * 1];
    }
    /* mov [device_put] -> r942 */
    memcpy(r942, r19, sizeof(int32_t) * 6);
    /* broadcast [broadcast_in_dim] -> r943 */
    for (long i19493 = 0; i19493 < 6; ++i19493) {
        long t19495 = i19493;
        long c194940 = t19495 / 6; t19495 %= 6;
        long c194941 = t19495 / 6; t19495 %= 6;
        long c194942 = t19495;
        r943[i19493] = r942[c194942 * 1];
    }
    /* add [add] -> r944 */
    for (long i19496 = 0; i19496 < 30; ++i19496) {
        long t19498 = i19496;
        long c194970 = t19498 / 30; t19498 %= 30;
        long c194971 = t19498 / 6; t19498 %= 6;
        long c194972 = t19498;
        r944[i19496] = add32(r943[c194972 * 1], r941[c194971 * 6 + c194972 * 1]);
    }
    /* convert [convert_element_type] -> r945 */
    for (long i19499 = 0; i19499 < 1; ++i19499) {
        r945[i19499] = (int32_t)r49[0];
    }
    /* max [max] -> r946 */
    for (long i19500 = 0; i19500 < 30; ++i19500) {
        r946[i19500] = max32(r945[0], r944[i19500]);
    }
    /* convert [convert_element_type] -> r947 */
    for (long i19501 = 0; i19501 < 1; ++i19501) {
        r947[i19501] = (int32_t)r50[0];
    }
    /* min [min] -> r948 */
    for (long i19502 = 0; i19502 < 30; ++i19502) {
        r948[i19502] = min32(r947[0], r946[i19502]);
    }
    /* broadcast [broadcast_in_dim] -> r949 */
    for (long i19503 = 0; i19503 < 6; ++i19503) {
        long t19505 = i19503;
        long c195040 = t19505 / 6; t19505 %= 6;
        long c195041 = t19505 / 6; t19505 %= 6;
        long c195042 = t19505;
        r949[i19503] = r942[c195042 * 1];
    }
    /* sub [sub] -> r950 */
    for (long i19506 = 0; i19506 < 30; ++i19506) {
        long t19508 = i19506;
        long c195070 = t19508 / 30; t19508 %= 30;
        long c195071 = t19508 / 6; t19508 %= 6;
        long c195072 = t19508;
        r950[i19506] = sub32(r949[c195072 * 1], r941[c195071 * 6 + c195072 * 1]);
    }
    /* convert [convert_element_type] -> r951 */
    for (long i19509 = 0; i19509 < 1; ++i19509) {
        r951[i19509] = (int32_t)r49[0];
    }
    /* max [max] -> r952 */
    for (long i19510 = 0; i19510 < 30; ++i19510) {
        r952[i19510] = max32(r951[0], r950[i19510]);
    }
    /* convert [convert_element_type] -> r953 */
    for (long i19511 = 0; i19511 < 1; ++i19511) {
        r953[i19511] = (int32_t)r50[0];
    }
    /* min [min] -> r954 */
    for (long i19512 = 0; i19512 < 30; ++i19512) {
        r954[i19512] = min32(r953[0], r952[i19512]);
    }
    /* abs [abs] -> r955 */
    for (long i19513 = 0; i19513 < 30; ++i19513) {
        r955[i19513] = abs32(r948[i19513]);
    }
    /* reduce_max [reduce_max] -> r956 */
    for (long i19514 = 0; i19514 < 5; ++i19514) {
        r956[i19514] = (-2147483647 - 1);
    }
    for (long i19515 = 0; i19515 < 30; ++i19515) {
        long t19517 = i19515;
        long c195160 = t19517 / 30; t19517 %= 30;
        long c195161 = t19517 / 6; t19517 %= 6;
        long c195162 = t19517;
        r956[c195160 * 5 + c195161 * 1] = max32(r956[c195160 * 5 + c195161 * 1], r955[i19515]);
    }
    /* sub [sub] -> r957 */
    for (long i19518 = 0; i19518 < 5; ++i19518) {
        r957[i19518] = sub32(r956[i19518], r62[0]);
    }
    /* loop [scan] -> r979 */
    memcpy(r958, r948, sizeof(int32_t) * 30);
    memcpy(r959, r62, sizeof(int32_t) * 1);
    memcpy(r960, r40, sizeof(int32_t) * 1);
    memcpy(r961, r957, sizeof(int32_t) * 5);
    memcpy(r962, r956, sizeof(int32_t) * 5);
    for (long t19519 = 0; t19519 < 12; ++t19519) {
        /* add [add] -> r963 */
        for (long i20520 = 0; i20520 < 1; ++i20520) {
            r963[i20520] = add32(r960[0], r30[0]);
        }
        /* add [add] -> r964 */
        for (long i20521 = 0; i20521 < 5; ++i20521) {
            r964[i20521] = add32(r961[i20521], r962[i20521]);
        }
        /* shra [shift_right_arithmetic] -> r965 */
        for (long i20522 = 0; i20522 < 5; ++i20522) {
            r965[i20522] = asr32(r964[i20522], 1);
        }
        /* broadcast [broadcast_in_dim] -> r966 */
        for (long i20523 = 0; i20523 < 5; ++i20523) {
            long t20525 = i20523;
            long c205240 = t20525 / 5; t20525 %= 5;
            long c205241 = t20525 / 1; t20525 %= 1;
            long c205242 = t20525;
            r966[i20523] = r965[c205241 * 1];
        }
        /* sub [sub] -> r967 */
        for (long i20526 = 0; i20526 < 30; ++i20526) {
            long t20528 = i20526;
            long c205270 = t20528 / 30; t20528 %= 30;
            long c205271 = t20528 / 6; t20528 %= 6;
            long c205272 = t20528;
            r967[i20526] = sub32(r958[c205271 * 6 + c205272 * 1], r966[c205271 * 1]);
        }
        /* max [max] -> r968 */
        for (long i20529 = 0; i20529 < 30; ++i20529) {
            r968[i20529] = max32(r967[i20529], r40[0]);
        }
        /* reduce_sum [reduce_sum] -> r969 */
        for (long i20530 = 0; i20530 < 5; ++i20530) {
            r969[i20530] = 0;
        }
        for (long i20531 = 0; i20531 < 30; ++i20531) {
            long t20533 = i20531;
            long c205320 = t20533 / 30; t20533 %= 30;
            long c205321 = t20533 / 6; t20533 %= 6;
            long c205322 = t20533;
            r969[c205320 * 5 + c205321 * 1] = add32(r969[c205320 * 5 + c205321 * 1], r968[i20531]);
        }
        /* neg [neg] -> r970 */
        for (long i20534 = 0; i20534 < 30; ++i20534) {
            r970[i20534] = neg32(r958[i20534]);
        }
        /* broadcast [broadcast_in_dim] -> r971 */
        for (long i20535 = 0; i20535 < 5; ++i20535) {
            long t20537 = i20535;
            long c205360 = t20537 / 5; t20537 %= 5;
            long c205361 = t20537 / 1; t20537 %= 1;
            long c205362 = t20537;
            r971[i20535] = r965[c205361 * 1];
        }
        /* sub [sub] -> r972 */
        for (long i20538 = 0; i20538 < 30; ++i20538) {
            long t20540 = i20538;
            long c205390 = t20540 / 30; t20540 %= 30;
            long c205391 = t20540 / 6; t20540 %= 6;
            long c205392 = t20540;
            r972[i20538] = sub32(r970[c205391 * 6 + c205392 * 1], r971[c205391 * 1]);
        }
        /* max [max] -> r973 */
        for (long i20541 = 0; i20541 < 30; ++i20541) {
            r973[i20541] = max32(r972[i20541], r40[0]);
        }
        /* reduce_sum [reduce_sum] -> r974 */
        for (long i20542 = 0; i20542 < 5; ++i20542) {
            r974[i20542] = 0;
        }
        for (long i20543 = 0; i20543 < 30; ++i20543) {
            long t20545 = i20543;
            long c205440 = t20545 / 30; t20545 %= 30;
            long c205441 = t20545 / 6; t20545 %= 6;
            long c205442 = t20545;
            r974[c205440 * 5 + c205441 * 1] = add32(r974[c205440 * 5 + c205441 * 1], r973[i20543]);
        }
        /* add [add] -> r975 */
        for (long i20546 = 0; i20546 < 5; ++i20546) {
            r975[i20546] = add32(r969[i20546], r974[i20546]);
        }
        /* gt [gt] -> r976 */
        for (long i20547 = 0; i20547 < 5; ++i20547) {
            r976[i20547] = r975[i20547] > r959[0] ? 1 : 0;
        }
        /* select_n [select_n] -> r977 */
        for (long i20548 = 0; i20548 < 5; ++i20548) {
            r977[i20548] = r976[i20548] == 0 ? r961[i20548] : (r965[i20548]);
        }
        /* select_n [select_n] -> r978 */
        for (long i20549 = 0; i20549 < 5; ++i20549) {
            r978[i20549] = r976[i20549] == 0 ? r965[i20549] : (r962[i20549]);
        }
        memcpy(r960, r963, sizeof(int32_t) * 1);
        memcpy(r961, r977, sizeof(int32_t) * 5);
        memcpy(r962, r978, sizeof(int32_t) * 5);
    }
    memcpy(r979, r960, sizeof(int32_t) * 1);
    memcpy(r980, r961, sizeof(int32_t) * 5);
    memcpy(r981, r962, sizeof(int32_t) * 5);
    /* abs [abs] -> r982 */
    for (long i20550 = 0; i20550 < 30; ++i20550) {
        r982[i20550] = abs32(r954[i20550]);
    }
    /* reduce_max [reduce_max] -> r983 */
    for (long i20551 = 0; i20551 < 5; ++i20551) {
        r983[i20551] = (-2147483647 - 1);
    }
    for (long i20552 = 0; i20552 < 30; ++i20552) {
        long t20554 = i20552;
        long c205530 = t20554 / 30; t20554 %= 30;
        long c205531 = t20554 / 6; t20554 %= 6;
        long c205532 = t20554;
        r983[c205530 * 5 + c205531 * 1] = max32(r983[c205530 * 5 + c205531 * 1], r982[i20552]);
    }
    /* sub [sub] -> r984 */
    for (long i20555 = 0; i20555 < 5; ++i20555) {
        r984[i20555] = sub32(r983[i20555], r62[0]);
    }
    /* loop [scan] -> r1006 */
    memcpy(r985, r954, sizeof(int32_t) * 30);
    memcpy(r986, r62, sizeof(int32_t) * 1);
    memcpy(r987, r40, sizeof(int32_t) * 1);
    memcpy(r988, r984, sizeof(int32_t) * 5);
    memcpy(r989, r983, sizeof(int32_t) * 5);
    for (long t20556 = 0; t20556 < 12; ++t20556) {
        /* add [add] -> r990 */
        for (long i21557 = 0; i21557 < 1; ++i21557) {
            r990[i21557] = add32(r987[0], r30[0]);
        }
        /* add [add] -> r991 */
        for (long i21558 = 0; i21558 < 5; ++i21558) {
            r991[i21558] = add32(r988[i21558], r989[i21558]);
        }
        /* shra [shift_right_arithmetic] -> r992 */
        for (long i21559 = 0; i21559 < 5; ++i21559) {
            r992[i21559] = asr32(r991[i21559], 1);
        }
        /* broadcast [broadcast_in_dim] -> r993 */
        for (long i21560 = 0; i21560 < 5; ++i21560) {
            long t21562 = i21560;
            long c215610 = t21562 / 5; t21562 %= 5;
            long c215611 = t21562 / 1; t21562 %= 1;
            long c215612 = t21562;
            r993[i21560] = r992[c215611 * 1];
        }
        /* sub [sub] -> r994 */
        for (long i21563 = 0; i21563 < 30; ++i21563) {
            long t21565 = i21563;
            long c215640 = t21565 / 30; t21565 %= 30;
            long c215641 = t21565 / 6; t21565 %= 6;
            long c215642 = t21565;
            r994[i21563] = sub32(r985[c215641 * 6 + c215642 * 1], r993[c215641 * 1]);
        }
        /* max [max] -> r995 */
        for (long i21566 = 0; i21566 < 30; ++i21566) {
            r995[i21566] = max32(r994[i21566], r40[0]);
        }
        /* reduce_sum [reduce_sum] -> r996 */
        for (long i21567 = 0; i21567 < 5; ++i21567) {
            r996[i21567] = 0;
        }
        for (long i21568 = 0; i21568 < 30; ++i21568) {
            long t21570 = i21568;
            long c215690 = t21570 / 30; t21570 %= 30;
            long c215691 = t21570 / 6; t21570 %= 6;
            long c215692 = t21570;
            r996[c215690 * 5 + c215691 * 1] = add32(r996[c215690 * 5 + c215691 * 1], r995[i21568]);
        }
        /* neg [neg] -> r997 */
        for (long i21571 = 0; i21571 < 30; ++i21571) {
            r997[i21571] = neg32(r985[i21571]);
        }
        /* broadcast [broadcast_in_dim] -> r998 */
        for (long i21572 = 0; i21572 < 5; ++i21572) {
            long t21574 = i21572;
            long c215730 = t21574 / 5; t21574 %= 5;
            long c215731 = t21574 / 1; t21574 %= 1;
            long c215732 = t21574;
            r998[i21572] = r992[c215731 * 1];
        }
        /* sub [sub] -> r999 */
        for (long i21575 = 0; i21575 < 30; ++i21575) {
            long t21577 = i21575;
            long c215760 = t21577 / 30; t21577 %= 30;
            long c215761 = t21577 / 6; t21577 %= 6;
            long c215762 = t21577;
            r999[i21575] = sub32(r997[c215761 * 6 + c215762 * 1], r998[c215761 * 1]);
        }
        /* max [max] -> r1000 */
        for (long i21578 = 0; i21578 < 30; ++i21578) {
            r1000[i21578] = max32(r999[i21578], r40[0]);
        }
        /* reduce_sum [reduce_sum] -> r1001 */
        for (long i21579 = 0; i21579 < 5; ++i21579) {
            r1001[i21579] = 0;
        }
        for (long i21580 = 0; i21580 < 30; ++i21580) {
            long t21582 = i21580;
            long c215810 = t21582 / 30; t21582 %= 30;
            long c215811 = t21582 / 6; t21582 %= 6;
            long c215812 = t21582;
            r1001[c215810 * 5 + c215811 * 1] = add32(r1001[c215810 * 5 + c215811 * 1], r1000[i21580]);
        }
        /* add [add] -> r1002 */
        for (long i21583 = 0; i21583 < 5; ++i21583) {
            r1002[i21583] = add32(r996[i21583], r1001[i21583]);
        }
        /* gt [gt] -> r1003 */
        for (long i21584 = 0; i21584 < 5; ++i21584) {
            r1003[i21584] = r1002[i21584] > r986[0] ? 1 : 0;
        }
        /* select_n [select_n] -> r1004 */
        for (long i21585 = 0; i21585 < 5; ++i21585) {
            r1004[i21585] = r1003[i21585] == 0 ? r988[i21585] : (r992[i21585]);
        }
        /* select_n [select_n] -> r1005 */
        for (long i21586 = 0; i21586 < 5; ++i21586) {
            r1005[i21586] = r1003[i21586] == 0 ? r992[i21586] : (r989[i21586]);
        }
        memcpy(r987, r990, sizeof(int32_t) * 1);
        memcpy(r988, r1004, sizeof(int32_t) * 5);
        memcpy(r989, r1005, sizeof(int32_t) * 5);
    }
    memcpy(r1006, r987, sizeof(int32_t) * 1);
    memcpy(r1007, r988, sizeof(int32_t) * 5);
    memcpy(r1008, r989, sizeof(int32_t) * 5);
    /* sub [sub] -> r1009 */
    for (long i21587 = 0; i21587 < 5; ++i21587) {
        r1009[i21587] = sub32(r981[i21587], r1008[i21587]);
    }
    /* shra [shift_right_arithmetic] -> r1010 */
    for (long i21588 = 0; i21588 < 5; ++i21588) {
        r1010[i21588] = asr32(r1009[i21588], 1);
    }
    /* convert [convert_element_type] -> r1011 */
    for (long i21589 = 0; i21589 < 1; ++i21589) {
        r1011[i21589] = (int32_t)r222[0];
    }
    /* max [max] -> r1012 */
    for (long i21590 = 0; i21590 < 5; ++i21590) {
        r1012[i21590] = max32(r1011[0], r1010[i21590]);
    }
    /* convert [convert_element_type] -> r1013 */
    for (long i21591 = 0; i21591 < 1; ++i21591) {
        r1013[i21591] = (int32_t)r223[0];
    }
    /* min [min] -> r1014 */
    for (long i21592 = 0; i21592 < 5; ++i21592) {
        r1014[i21592] = min32(r1013[0], r1012[i21592]);
    }
    /* sub [sub] -> r1015 */
    for (long i21593 = 0; i21593 < 1; ++i21593) {
        r1015[i21593] = sub32(r821[i21593], r922[i21593]);
    }
    /* add [add] -> r1016 */
    for (long i21594 = 0; i21594 < 1; ++i21594) {
        r1016[i21594] = add32(r1015[i21594], r30[0]);
    }
    /* max [max] -> r1017 */
    for (long i21595 = 0; i21595 < 1; ++i21595) {
        r1017[i21595] = max32(r1016[i21595], r40[0]);
    }
    /* shra [shift_right_arithmetic] -> r1018 */
    for (long i21596 = 0; i21596 < 1; ++i21596) {
        r1018[i21596] = asr32(r1017[i21596], 1);
    }
    /* concat [concatenate] -> r1019 */
    for (long i21597 = 0; i21597 < 15; ++i21597) {
        long t21599 = i21597;
        long c215980 = t21599 / 15; t21599 %= 15;
        long c215981 = t21599;
        r1019[c215980 * 20 + (c215981 + 0) * 1] = r5[i21597];
    }
    for (long i21600 = 0; i21600 < 5; ++i21600) {
        long t21602 = i21600;
        long c216010 = t21602 / 5; t21602 %= 5;
        long c216011 = t21602;
        r1019[c216010 * 20 + (c216011 + 15) * 1] = r1014[i21600];
    }
    /* shl [shift_left] -> r1020 */
    for (long i21603 = 0; i21603 < 20; ++i21603) {
        r1020[i21603] = shl32(r1019[i21603], 1);
    }
    /* mov [device_put] -> r1021 */
    memcpy(r1021, r18, sizeof(int32_t) * 80);
    /* rev [rev] -> r1022 */
    for (long i21604 = 0; i21604 < 80; ++i21604) {
        long t21606 = i21604;
        long c216050 = t21606 / 16; t21606 %= 16;
        long c216051 = t21606;
        r1022[i21604] = r1021[c216050 * 16 + (16 - 1 - c216051) * 1];
    }
    /* reshape [reshape] -> r1023 */
    memcpy(r1023, r1022, sizeof(int32_t) * 80);
    /* iota [iota] -> r1024 */
    for (long i21607 = 0; i21607 < 5; ++i21607) {
        long t21609 = i21607;
        long c216080 = t21609;
        r1024[i21607] = (int32_t)c216080;
    }
    /* broadcast [broadcast_in_dim] -> r1025 */
    for (long i21610 = 0; i21610 < 5; ++i21610) {
        long t21612 = i21610;
        long c216110 = t21612 / 1; t21612 %= 1;
        long c216111 = t21612;
        r1025[i21610] = r1024[c216110 * 1];
    }
    /* iota [iota] -> r1026 */
    for (long i21613 = 0; i21613 < 16; ++i21613) {
        long t21615 = i21613;
        long c216140 = t21615;
        r1026[i21613] = (int32_t)c216140;
    }
    /* broadcast [broadcast_in_dim] -> r1027 */
    for (long i21616 = 0; i21616 < 16; ++i21616) {
        long t21618 = i21616;
        long c216170 = t21618 / 16; t21618 %= 16;
        long c216171 = t21618;
        r1027[i21616] = r1026[c216171 * 1];
    }
    /* add [add] -> r1028 */
    for (long i21619 = 0; i21619 < 80; ++i21619) {
        long t21621 = i21619;
        long c216200 = t21621 / 16; t21621 %= 16;
        long c216201 = t21621;
        r1028[i21619] = add32(r1025[c216200 * 1], r1027[c216201 * 1]);
    }
    /* lt [lt] -> r1029 */
    for (long i21622 = 0; i21622 < 80; ++i21622) {
        r1029[i21622] = r1028[i21622] < r40[0] ? 1 : 0;
    }
    /* add [add] -> r1031 */
    for (long i21623 = 0; i21623 < 80; ++i21623) {
        r1031[i21623] = add32(r1028[i21623], r1030[0]);
    }
    /* select_n [select_n] -> r1032 */
    for (long i21624 = 0; i21624 < 80; ++i21624) {
        r1032[i21624] = r1029[i21624] == 0 ? r1028[i21624] : (r1031[i21624]);
    }
    /* broadcast [broadcast_in_dim] -> r1033 */
    for (long i21625 = 0; i21625 < 80; ++i21625) {
        long t21627 = i21625;
        long c216260 = t21627 / 16; t21627 %= 16;
        long c216261 = t21627 / 1; t21627 %= 1;
        long c216262 = t21627;
        r1033[i21625] = r1032[c216260 * 16 + c216261 * 1];
    }
    /* gather [gather] -> r1034 */
    for (long i21628 = 0; i21628 < 80; ++i21628) {
        long t21630 = i21628;
        long c216290 = t21630 / 80; t21630 %= 80;
        long c216291 = t21630 / 16; t21630 %= 16;
        long c216292 = t21630;
        long row21631 = c216291 * 16 + c216292 * 1;
        long s21632 = clamp_start((long)r1033[row21631 + 0], 20, 1);
        r1034[i21628] = r1020[c216290 * 20 + s21632 * 1];
    }
    /* broadcast [broadcast_in_dim] -> r1035 */
    for (long i21633 = 0; i21633 < 80; ++i21633) {
        long t21635 = i21633;
        long c216340 = t21635 / 80; t21635 %= 80;
        long c216341 = t21635 / 80; t21635 %= 80;
        long c216342 = t21635 / 16; t21635 %= 16;
        long c216343 = t21635;
        r1035[i21633] = r1034[c216342 * 16 + c216343 * 1];
    }
    /* add [add] -> r1036 */
    for (long i21636 = 0; i21636 < 400; ++i21636) {
        long t21638 = i21636;
        long c216370 = t21638 / 80; t21638 %= 80;
        long c216371 = t21638 / 80; t21638 %= 80;
        long c216372 = t21638 / 16; t21638 %= 16;
        long c216373 = t21638;
        r1036[i21636] = add32(r1023[c216370 * 16 + c216373 * 1], r1035[c216372 * 16 + c216373 * 1]);
    }
    /* convert [convert_element_type] -> r1037 */
    for (long i21639 = 0; i21639 < 1; ++i21639) {
        r1037[i21639] = (int32_t)r49[0];
    }
    /* max [max] -> r1038 */
    for (long i21640 = 0; i21640 < 400; ++i21640) {
        r1038[i21640] = max32(r1037[0], r1036[i21640]);
    }
    /* convert [convert_element_type] -> r1039 */
    for (long i21641 = 0; i21641 < 1; ++i21641) {
        r1039[i21641] = (int32_t)r50[0];
    }
    /* min [min] -> r1040 */
    for (long i21642 = 0; i21642 < 400; ++i21642) {
        r1040[i21642] = min32(r1039[0], r1038[i21642]);
    }
    /* sub [sub] -> r1041 */
    for (long i21643 = 0; i21643 < 400; ++i21643) {
        long t21645 = i21643;
        long c216440 = t21645 / 80; t21645 %= 80;
        long c216441 = t21645 / 80; t21645 %= 80;
        long c216442 = t21645 / 16; t21645 %= 16;
        long c216443 = t21645;
        r1041[i21643] = sub32(r1023[c216440 * 16 + c216443 * 1], r1035[c216442 * 16 + c216443 * 1]);
    }
    /* convert [convert_element_type] -> r1042 */
    for (long i21646 = 0; i21646 < 1; ++i21646) {
        r1042[i21646] = (int32_t)r49[0];
    }
    /* max [max] -> r1043 */
    for (long i21647 = 0; i21647 < 400; ++i21647) {
        r1043[i21647] = max32(r1042[0], r1041[i21647]);
    }
    /* convert [convert_element_type] -> r1044 */
    for (long i21648 = 0; i21648 < 1; ++i21648) {
        r1044[i21648] = (int32_t)r50[0];
    }
    /* min [min] -> r1045 */
    for (long i21649 = 0; i21649 < 400; ++i21649) {
        r1045[i21649] = min32(r1044[0], r1043[i21649]);
    }
    /* abs [abs] -> r1046 */
    for (long i21650 = 0; i21650 < 400; ++i21650) {
        r1046[i21650] = abs32(r1040[i21650]);
    }
    /* reduce_max [reduce_max] -> r1047 */
    for (long i21651 = 0; i21651 < 25; ++i21651) {
        r1047[i21651] = (-2147483647 - 1);
    }
    for (long i21652 = 0; i21652 < 400; ++i21652) {
        long t21654 = i21652;
        long c216530 = t21654 / 80; t21654 %= 80;
        long c216531 = t21654 / 80; t21654 %= 80;
        long c216532 = t21654 / 16; t21654 %= 16;
        long c216533 = t21654;
        r1047[c216530 * 5 + c216531 * 5 + c216532 * 1] = max32(r1047[c216530 * 5 + c216531 * 5 + c216532 * 1], r1046[i21652]);
    }
    /* sub [sub] -> r1048 */
    for (long i21655 = 0; i21655 < 25; ++i21655) {
        r1048[i21655] = sub32(r1047[i21655], r62[0]);
    }
    /* loop [scan] -> r1070 */
    memcpy(r1049, r1040, sizeof(int32_t) * 400);
    memcpy(r1050, r62, sizeof(int32_t) * 1);
    memcpy(r1051, r40, sizeof(int32_t) * 1);
    memcpy(r1052, r1048, sizeof(int32_t) * 25);
    memcpy(r1053, r1047, sizeof(int32_t) * 25);
    for (long t21656 = 0; t21656 < 12; ++t21656) {
        /* add [add] -> r1054 */
        for (long i22657 = 0; i22657 < 1; ++i22657) {
            r1054[i22657] = add32(r1051[0], r30[0]);
        }
        /* add [add] -> r1055 */
        for (long i22658 = 0; i22658 < 25; ++i22658) {
            r1055[i22658] = add32(r1052[i22658], r1053[i22658]);
        }
        /* shra [shift_right_arithmetic] -> r1056 */
        for (long i22659 = 0; i22659 < 25; ++i22659) {
            r1056[i22659] = asr32(r1055[i22659], 1);
        }
        /* broadcast [broadcast_in_dim] -> r1057 */
        for (long i22660 = 0; i22660 < 25; ++i22660) {
            long t22662 = i22660;
            long c226610 = t22662 / 5; t22662 %= 5;
            long c226611 = t22662 / 5; t22662 %= 5;
            long c226612 = t22662 / 1; t22662 %= 1;
            long c226613 = t22662;
            r1057[i22660] = r1056[c226610 * 5 + c226612 * 1];
        }
        /* sub [sub] -> r1058 */
        for (long i22663 = 0; i22663 < 400; ++i22663) {
            long t22665 = i22663;
            long c226640 = t22665 / 80; t22665 %= 80;
            long c226641 = t22665 / 80; t22665 %= 80;
            long c226642 = t22665 / 16; t22665 %= 16;
            long c226643 = t22665;
            r1058[i22663] = sub32(r1049[c226640 * 80 + c226642 * 16 + c226643 * 1], r1057[c226640 * 5 + c226642 * 1]);
        }
        /* max [max] -> r1059 */
        for (long i22666 = 0; i22666 < 400; ++i22666) {
            r1059[i22666] = max32(r1058[i22666], r40[0]);
        }
        /* reduce_sum [reduce_sum] -> r1060 */
        for (long i22667 = 0; i22667 < 25; ++i22667) {
            r1060[i22667] = 0;
        }
        for (long i22668 = 0; i22668 < 400; ++i22668) {
            long t22670 = i22668;
            long c226690 = t22670 / 80; t22670 %= 80;
            long c226691 = t22670 / 80; t22670 %= 80;
            long c226692 = t22670 / 16; t22670 %= 16;
            long c226693 = t22670;
            r1060[c226690 * 5 + c226691 * 5 + c226692 * 1] = add32(r1060[c226690 * 5 + c226691 * 5 + c226692 * 1], r1059[i22668]);
        }
        /* neg [neg] -> r1061 */
        for (long i22671 = 0; i22671 < 400; ++i22671) {
            r1061[i22671] = neg32(r1049[i22671]);
        }
        /* broadcast [broadcast_in_dim] -> r1062 */
        for (long i22672 = 0; i22672 < 25; ++i22672) {
            long t22674 = i22672;
            long c226730 = t22674 / 5; t22674 %= 5;
            long c226731 = t22674 / 5; t22674 %= 5;
            long c226732 = t22674 / 1; t22674 %= 1;
            long c226733 = t22674;
            r1062[i22672] = r1056[c226730 * 5 + c226732 * 1];
        }
        /* sub [sub] -> r1063 */
        for (long i22675 = 0; i22675 < 400; ++i22675) {
            long t22677 = i22675;
            long c226760 = t22677 / 80; t22677 %= 80;
            long c226761 = t22677 / 80; t22677 %= 80;
            long c226762 = t22677 / 16; t22677 %= 16;
            long c226763 = t22677;
            r1063[i22675] = sub32(r1061[c226760 * 80 + c226762 * 16 + c226763 * 1], r1062[c226760 * 5 + c226762 * 1]);
        }
        /* max [max] -> r1064 */
        for (long i22678 = 0; i22678 < 400; ++i22678) {
            r1064[i22678] = max32(r1063[i22678], r40[0]);
        }
        /* reduce_sum [reduce_sum] -> r1065 */
        for (long i22679 = 0; i22679 < 25; ++i22679) {
            r1065[i22679] = 0;
        }
        for (long i22680 = 0; i22680 < 400; ++i22680) {
            long t22682 = i22680;
            long c226810 = t22682 / 80; t22682 %= 80;
            long c226811 = t22682 / 80; t22682 %= 80;
            long c226812 = t22682 / 16; t22682 %= 16;
            long c226813 = t22682;
            r1065[c226810 * 5 + c226811 * 5 + c226812 * 1] = add32(r1065[c226810 * 5 + c226811 * 5 + c226812 * 1], r1064[i22680]);
        }
        /* add [add] -> r1066 */
        for (long i22683 = 0; i22683 < 25; ++i22683) {
            r1066[i22683] = add32(r1060[i22683], r1065[i22683]);
        }
        /* gt [gt] -> r1067 */
        for (long i22684 = 0; i22684 < 25; ++i22684) {
            r1067[i22684] = r1066[i22684] > r1050[0] ? 1 : 0;
        }
        /* select_n [select_n] -> r1068 */
        for (long i22685 = 0; i22685 < 25; ++i22685) {
            r1068[i22685] = r1067[i22685] == 0 ? r1052[i22685] : (r1056[i22685]);
        }
        /* select_n [select_n] -> r1069 */
        for (long i22686 = 0; i22686 < 25; ++i22686) {
            r1069[i22686] = r1067[i22686] == 0 ? r1056[i22686] : (r1053[i22686]);
        }
        memcpy(r1051, r1054, sizeof(int32_t) * 1);
        memcpy(r1052, r1068, sizeof(int32_t) * 25);
        memcpy(r1053, r1069, sizeof(int32_t) * 25);
    }
    memcpy(r1070, r1051, sizeof(int32_t) * 1);
    memcpy(r1071, r1052, sizeof(int32_t) * 25);
    memcpy(r1072, r1053, sizeof(int32_t) * 25);
    /* abs [abs] -> r1073 */
    for (long i22687 = 0; i22687 < 400; ++i22687) {
        r1073[i22687] = abs32(r1045[i22687]);
    }
    /* reduce_max [reduce_max] -> r1074 */
    for (long i22688 = 0; i22688 < 25; ++i22688) {
        r1074[i22688] = (-2147483647 - 1);
    }
    for (long i22689 = 0; i22689 < 400; ++i22689) {
        long t22691 = i22689;
        long c226900 = t22691 / 80; t22691 %= 80;
        long c226901 = t22691 / 80; t22691 %= 80;
        long c226902 = t22691 / 16; t22691 %= 16;
        long c226903 = t22691;
        r1074[c226900 * 5 + c226901 * 5 + c226902 * 1] = max32(r1074[c226900 * 5 + c226901 * 5 + c226902 * 1], r1073[i22689]);
    }
    /* sub [sub] -> r1075 */
    for (long i22692 = 0; i22692 < 25; ++i22692) {
        r1075[i22692] = sub32(r1074[i22692], r62[0]);
    }
    /* loop [scan] -> r1097 */
    memcpy(r1076, r1045, sizeof(int32_t) * 400);
    memcpy(r1077, r62, sizeof(int32_t) * 1);
    memcpy(r1078, r40, sizeof(int32_t) * 1);
    memcpy(r1079, r1075, sizeof(int32_t) * 25);
    memcpy(r1080, r1074, sizeof(int32_t) * 25);
    for (long t22693 = 0; t22693 < 12; ++t22693) {
        /* add [add] -> r1081 */
        for (long i23694 = 0; i23694 < 1; ++i23694) {
            r1081[i23694] = add32(r1078[0], r30[0]);
        }
        /* add [add] -> r1082 */
        for (long i23695 = 0; i23695 < 25; ++i23695) {
            r1082[i23695] = add32(r1079[i23695], r1080[i23695]);
        }
        /* shra [shift_right_arithmetic] -> r1083 */
        for (long i23696 = 0; i23696 < 25; ++i23696) {
            r1083[i23696] = asr32(r1082[i23696], 1);
        }
        /* broadcast [broadcast_in_dim] -> r1084 */
        for (long i23697 = 0; i23697 < 25; ++i23697) {
            long t23699 = i23697;
            long c236980 = t23699 / 5; t23699 %= 5;
            long c236981 = t23699 / 5; t23699 %= 5;
            long c236982 = t23699 / 1; t23699 %= 1;
            long c236983 = t23699;
            r1084[i23697] = r1083[c236980 * 5 + c236982 * 1];
        }
        /* sub [sub] -> r1085 */
        for (long i23700 = 0; i23700 < 400; ++i23700) {
            long t23702 = i23700;
            long c237010 = t23702 / 80; t23702 %= 80;
            long c237011 = t23702 / 80; t23702 %= 80;
            long c237012 = t23702 / 16; t23702 %= 16;
            long c237013 = t23702;
            r1085[i23700] = sub32(r1076[c237010 * 80 + c237012 * 16 + c237013 * 1], r1084[c237010 * 5 + c237012 * 1]);
        }
        /* max [max] -> r1086 */
        for (long i23703 = 0; i23703 < 400; ++i23703) {
            r1086[i23703] = max32(r1085[i23703], r40[0]);
        }
        /* reduce_sum [reduce_sum] -> r1087 */
        for (long i23704 = 0; i23704 < 25; ++i23704) {
            r1087[i23704] = 0;
        }
        for (long i23705 = 0; i23705 < 400; ++i23705) {
            long t23707 = i23705;
            long c237060 = t23707 / 80; t23707 %= 80;
            long c237061 = t23707 / 80; t23707 %= 80;
            long c237062 = t23707 / 16; t23707 %= 16;
            long c237063 = t23707;
            r1087[c237060 * 5 + c237061 * 5 + c237062 * 1] = add32(r1087[c237060 * 5 + c237061 * 5 + c237062 * 1], r1086[i23705]);
        }
        /* neg [neg] -> r1088 */
        for (long i23708 = 0; i23708 < 400; ++i23708) {
            r1088[i23708] = neg32(r1076[i23708]);
        }
        /* broadcast [broadcast_in_dim] -> r1089 */
        for (long i23709 = 0; i23709 < 25; ++i23709) {
            long t23711 = i23709;
            long c237100 = t23711 / 5; t23711 %= 5;
            long c237101 = t23711 / 5; t23711 %= 5;
            long c237102 = t23711 / 1; t23711 %= 1;
            long c237103 = t23711;
            r1089[i23709] = r1083[c237100 * 5 + c237102 * 1];
        }
        /* sub [sub] -> r1090 */
        for (long i23712 = 0; i23712 < 400; ++i23712) {
            long t23714 = i23712;
            long c237130 = t23714 / 80; t23714 %= 80;
            long c237131 = t23714 / 80; t23714 %= 80;
            long c237132 = t23714 / 16; t23714 %= 16;
            long c237133 = t23714;
            r1090[i23712] = sub32(r1088[c237130 * 80 + c237132 * 16 + c237133 * 1], r1089[c237130 * 5 + c237132 * 1]);
        }
        /* max [max] -> r1091 */
        for (long i23715 = 0; i23715 < 400; ++i23715) {
            r1091[i23715] = max32(r1090[i23715], r40[0]);
        }
        /* reduce_sum [reduce_sum] -> r1092 */
        for (long i23716 = 0; i23716 < 25; ++i23716) {
            r1092[i23716] = 0;
        }
        for (long i23717 = 0; i23717 < 400; ++i23717) {
            long t23719 = i23717;
            long c237180 = t23719 / 80; t23719 %= 80;
            long c237181 = t23719 / 80; t23719 %= 80;
            long c237182 = t23719 / 16; t23719 %= 16;
            long c237183 = t23719;
            r1092[c237180 * 5 + c237181 * 5 + c237182 * 1] = add32(r1092[c237180 * 5 + c237181 * 5 + c237182 * 1], r1091[i23717]);
        }
        /* add [add] -> r1093 */
        for (long i23720 = 0; i23720 < 25; ++i23720) {
            r1093[i23720] = add32(r1087[i23720], r1092[i23720]);
        }
        /* gt [gt] -> r1094 */
        for (long i23721 = 0; i23721 < 25; ++i23721) {
            r1094[i23721] = r1093[i23721] > r1077[0] ? 1 : 0;
        }
        /* select_n [select_n] -> r1095 */
        for (long i23722 = 0; i23722 < 25; ++i23722) {
            r1095[i23722] = r1094[i23722] == 0 ? r1079[i23722] : (r1083[i23722]);
        }
        /* select_n [select_n] -> r1096 */
        for (long i23723 = 0; i23723 < 25; ++i23723) {
            r1096[i23723] = r1094[i23723] == 0 ? r1083[i23723] : (r1080[i23723]);
        }
        memcpy(r1078, r1081, sizeof(int32_t) * 1);
        memcpy(r1079, r1095, sizeof(int32_t) * 25);
        memcpy(r1080, r1096, sizeof(int32_t) * 25);
    }
    memcpy(r1097, r1078, sizeof(int32_t) * 1);
    memcpy(r1098, r1079, sizeof(int32_t) * 25);
    memcpy(r1099, r1080, sizeof(int32_t) * 25);
    /* sub [sub] -> r1100 */
    for (long i23724 = 0; i23724 < 25; ++i23724) {
        r1100[i23724] = sub32(r1072[i23724], r1099[i23724]);
    }
    /* transpose [transpose] -> r1101 */
    for (long i23725 = 0; i23725 < 25; ++i23725) {
        long t23727 = i23725;
        long c237260 = t23727 / 25; t23727 %= 25;
        long c237261 = t23727 / 5; t23727 %= 5;
        long c237262 = t23727;
        r1101[i23725] = r1100[c237260 * 5 + c237261 * 5 + c237262 * 1];
    }
    /* broadcast [broadcast_in_dim] -> r1102 */
    for (long i23728 = 0; i23728 < 1; ++i23728) {
        long t23730 = i23728;
        long c237290 = t23730 / 1; t23730 %= 1;
        long c237291 = t23730;
        r1102[i23728] = r1018[0];
    }
    /* max [max] -> r1103 */
    for (long i23731 = 0; i23731 < 25; ++i23731) {
        r1103[i23731] = max32(r1101[i23731], r40[0]);
    }
    /* iota [iota] -> r1104 */
    for (long i23732 = 0; i23732 < 25; ++i23732) {
        long t23734 = i23732;
        long c237330 = t23734 / 25; t23734 %= 25;
        long c237331 = t23734 / 5; t23734 %= 5;
        long c237332 = t23734;
        r1104[i23732] = (int32_t)c237332;
    }
    /* broadcast [broadcast_in_dim] -> r1105 */
    for (long i23735 = 0; i23735 < 1; ++i23735) {
        long t23737 = i23735;
        long c237360 = t23737 / 1; t23737 %= 1;
        long c237361 = t23737 / 1; t23737 %= 1;
        long c237362 = t23737;
        r1105[i23735] = r1102[0];
    }
    /* lt [lt] -> r1106 */
    for (long i23738 = 0; i23738 < 25; ++i23738) {
        long t23740 = i23738;
        long c237390 = t23740 / 25; t23740 %= 25;
        long c237391 = t23740 / 5; t23740 %= 5;
        long c237392 = t23740;
        r1106[i23738] = r1104[c237391 * 5 + c237392 * 1] < r1105[0] ? 1 : 0;
    }
    /* convert [convert_element_type] -> r1107 */
    for (long i23741 = 0; i23741 < 1; ++i23741) {
        r1107[i23741] = (int32_t)r40[0];
    }
    /* broadcast [broadcast_in_dim] -> r1108 */
    for (long i23742 = 0; i23742 < 25; ++i23742) {
        long t23744 = i23742;
        long c237430 = t23744 / 25; t23744 %= 25;
        long c237431 = t23744 / 5; t23744 %= 5;
        long c237432 = t23744;
        r1108[i23742] = r1107[0];
    }
    /* select_n [select_n] -> r1109 */
    for (long i23745 = 0; i23745 < 25; ++i23745) {
        r1109[i23745] = r1106[i23745] == 0 ? r1108[i23745] : (r1103[i23745]);
    }
    /* reduce_sum [reduce_sum] -> r1110 */
    for (long i23746 = 0; i23746 < 5; ++i23746) {
        r1110[i23746] = 0;
    }
    for (long i23747 = 0; i23747 < 25; ++i23747) {
        long t23749 = i23747;
        long c237480 = t23749 / 25; t23749 %= 25;
        long c237481 = t23749 / 5; t23749 %= 5;
        long c237482 = t23749;
        r1110[c237480 * 5 + c237481 * 1] = add32(r1110[c237480 * 5 + c237481 * 1], r1109[i23747]);
    }
    /* shl [shift_left] -> r1112 */
    for (long i23750 = 0; i23750 < 5; ++i23750) {
        r1112[i23750] = shl32(r1110[i23750], 5);
    }
    /* lt [lt] -> r1113 */
    for (long i23751 = 0; i23751 < 1; ++i23751) {
        r1113[i23751] = r1018[i23751] < r40[0] ? 1 : 0;
    }
    /* add [add] -> r1114 */
    for (long i23752 = 0; i23752 < 1; ++i23752) {
        r1114[i23752] = add32(r1018[i23752], r1030[0]);
    }
    /* select_n [select_n] -> r1115 */
    for (long i23753 = 0; i23753 < 1; ++i23753) {
        r1115[i23753] = r1113[i23753] == 0 ? r1018[i23753] : (r1114[i23753]);
    }
    /* broadcast [broadcast_in_dim] -> r1116 */
    for (long i23754 = 0; i23754 < 1; ++i23754) {
        long t23756 = i23754;
        long c237550 = t23756 / 1; t23756 %= 1;
        long c237551 = t23756;
        r1116[i23754] = r1115[0];
    }
    /* gather [gather] -> r1117 */
    for (long i23757 = 0; i23757 < 15; ++i23757) {
        long t23759 = i23757;
        long c237580 = t23759 / 15; t23759 %= 15;
        long c237581 = t23759;
        long row23760 = c237580 * 1;
        long s23761 = clamp_start((long)r1116[row23760 + 0], 20, 15);
        r1117[i23757] = r1019[c237580 * 20 + (s23761 + c237581) * 1];
    }
    /* add [add] -> r1118 */
    for (long i23762 = 0; i23762 < 1; ++i23762) {
        r1118[i23762] = add32(r11[i23762], r1018[i23762]);
    }
    /* concat [concatenate] -> r1119 */
    for (long i23763 = 0; i23763 < 5; ++i23763) {
        long t23765 = i23763;
        long c237640 = t23765 / 5; t23765 %= 5;
        long c237641 = t23765;
        r1119[c237640 * 30 + (c237641 + 0) * 1] = r126[i23763];
    }
    for (long i23766 = 0; i23766 < 5; ++i23766) {
        long t23768 = i23766;
        long c237670 = t23768 / 5; t23768 %= 5;
        long c237671 = t23768;
        r1119[c237670 * 30 + (c237671 + 5) * 1] = r324[i23766];
    }
    for (long i23769 = 0; i23769 < 5; ++i23769) {
        long t23771 = i23769;
        long c237700 = t23771 / 5; t23771 %= 5;
        long c237701 = t23771;
        r1119[c237700 * 30 + (c237701 + 10) * 1] = r521[i23769];
    }
    for (long i23772 = 0; i23772 < 5; ++i23772) {
        long t23774 = i23772;
        long c237730 = t23774 / 5; t23774 %= 5;
        long c237731 = t23774;
        r1119[c237730 * 30 + (c237731 + 15) * 1] = r718[i23772];
    }
    for (long i23775 = 0; i23775 < 5; ++i23775) {
        long t23777 = i23775;
        long c237760 = t23777 / 5; t23777 %= 5;
        long c237761 = t23777;
        r1119[c237760 * 30 + (c237761 + 20) * 1] = r915[i23775];
    }
    for (long i23778 = 0; i23778 < 5; ++i23778) {
        long t23780 = i23778;
        long c237790 = t23780 / 5; t23780 %= 5;
        long c237791 = t23780;
        r1119[c237790 * 30 + (c237791 + 25) * 1] = r1112[i23778];
    }
    /* add [add] -> r1120 */
    for (long i23781 = 0; i23781 < 30; ++i23781) {
        r1120[i23781] = add32(r12[i23781], r1119[i23781]);
    }
    /* add [add] -> r1121 */
    for (long i23782 = 0; i23782 < 1; ++i23782) {
        r1121[i23782] = add32(r14[i23782], r17[i23782]);
    }
    /* mov [device_put] -> r1122 */
    memcpy(r1122, r20, sizeof(int32_t) * 30);
    /* broadcast [broadcast_in_dim] -> r1123 */
    for (long i23783 = 0; i23783 < 30; ++i23783) {
        long t23785 = i23783;
        long c237840 = t23785 / 30; t23785 %= 30;
        long c237841 = t23785;
        r1123[i23783] = r1122[c237841 * 1];
    }
    /* sub [sub] -> r1124 */
    for (long i23786 = 0; i23786 < 30; ++i23786) {
        r1124[i23786] = sub32(r1120[i23786], r1123[i23786]);
    }
    /* mov [device_put] -> r1125 */
    memcpy(r1125, r21, sizeof(int32_t) * 30);
    /* ge [ge] -> r1126 */
    for (long i23787 = 0; i23787 < 30; ++i23787) {
        r1126[i23787] = r1125[i23787] >= r40[0] ? 1 : 0;
    }
    /* max [max] -> r1127 */
    for (long i23788 = 0; i23788 < 30; ++i23788) {
        r1127[i23788] = max32(r1125[i23788], r40[0]);
    }
    /* broadcast [broadcast_in_dim] -> r1128 */
    for (long i23789 = 0; i23789 < 30; ++i23789) {
        long t23791 = i23789;
        long c237900 = t23791 / 30; t23791 %= 30;
        long c237901 = t23791;
        r1128[i23789] = r1127[c237901 * 1];
    }
    /* shl [shift_left] -> r1129 */
    for (long i23792 = 0; i23792 < 30; ++i23792) {
        r1129[i23792] = shl32(r1124[i23792], r1128[i23792]);
    }
    /* neg [neg] -> r1130 */
    for (long i23793 = 0; i23793 < 30; ++i23793) {
        r1130[i23793] = neg32(r1125[i23793]);
    }
    /* max [max] -> r1131 */
    for (long i23794 = 0; i23794 < 30; ++i23794) {
        r1131[i23794] = max32(r1130[i23794], r40[0]);
    }
    /* broadcast [broadcast_in_dim] -> r1132 */
    for (long i23795 = 0; i23795 < 30; ++i23795) {
        long t23797 = i23795;
        long c237960 = t23797 / 30; t23797 %= 30;
        long c237961 = t23797;
        r1132[i23795] = r1131[c237961 * 1];
    }
    /* shra [shift_right_arithmetic] -> r1133 */
    for (long i23798 = 0; i23798 < 30; ++i23798) {
        r1133[i23798] = asr32(r1124[i23798], r1132[i23798]);
    }
    /* broadcast [broadcast_in_dim] -> r1134 */
    for (long i23799 = 0; i23799 < 30; ++i23799) {
        long t23801 = i23799;
        long c238000 = t23801 / 30; t23801 %= 30;
        long c238001 = t23801;
        r1134[i23799] = r1126[c238001 * 1];
    }
    /* select_n [select_n] -> r1135 */
    for (long i23802 = 0; i23802 < 30; ++i23802) {
        r1135[i23802] = r1134[i23802] == 0 ? r1133[i23802] : (r1129[i23802]);
    }
    /* mov [device_put] -> r1136 */
    memcpy(r1136, r22, sizeof(int32_t) * 30);
    /* ge [ge] -> r1137 */
    for (long i23803 = 0; i23803 < 30; ++i23803) {
        r1137[i23803] = r1136[i23803] >= r40[0] ? 1 : 0;
    }
    /* max [max] -> r1138 */
    for (long i23804 = 0; i23804 < 30; ++i23804) {
        r1138[i23804] = max32(r1136[i23804], r40[0]);
    }
    /* broadcast [broadcast_in_dim] -> r1139 */
    for (long i23805 = 0; i23805 < 30; ++i23805) {
        long t23807 = i23805;
        long c238060 = t23807 / 30; t23807 %= 30;
        long c238061 = t23807;
        r1139[i23805] = r1138[c238061 * 1];
    }
    /* shl [shift_left] -> r1140 */
    for (long i23808 = 0; i23808 < 30; ++i23808) {
        r1140[i23808] = shl32(r1124[i23808], r1139[i23808]);
    }
    /* neg [neg] -> r1141 */
    for (long i23809 = 0; i23809 < 30; ++i23809) {
        r1141[i23809] = neg32(r1136[i23809]);
    }
    /* max [max] -> r1142 */
    for (long i23810 = 0; i23810 < 30; ++i23810) {
        r1142[i23810] = max32(r1141[i23810], r40[0]);
    }
    /* broadcast [broadcast_in_dim] -> r1143 */
    for (long i23811 = 0; i23811 < 30; ++i23811) {
        long t23813 = i23811;
        long c238120 = t23813 / 30; t23813 %= 30;
        long c238121 = t23813;
        r1143[i23811] = r1142[c238121 * 1];
    }
    /* shra [shift_right_arithmetic] -> r1144 */
    for (long i23814 = 0; i23814 < 30; ++i23814) {
        r1144[i23814] = asr32(r1124[i23814], r1143[i23814]);
    }
    /* broadcast [broadcast_in_dim] -> r1145 */
    for (long i23815 = 0; i23815 < 30; ++i23815) {
        long t23817 = i23815;
        long c238160 = t23817 / 30; t23817 %= 30;
        long c238161 = t23817;
        r1145[i23815] = r1137[c238161 * 1];
    }
    /* select_n [select_n] -> r1146 */
    for (long i23818 = 0; i23818 < 30; ++i23818) {
        r1146[i23818] = r1145[i23818] == 0 ? r1144[i23818] : (r1140[i23818]);
    }
    /* mov [device_put] -> r1147 */
    memcpy(r1147, r20, sizeof(int32_t) * 30);
    /* gt [gt] -> r1148 */
    for (long i23819 = 0; i23819 < 30; ++i23819) {
        r1148[i23819] = r1147[i23819] > r40[0] ? 1 : 0;
    }
    /* add [add] -> r1149 */
    for (long i23820 = 0; i23820 < 30; ++i23820) {
        r1149[i23820] = add32(r1135[i23820], r1146[i23820]);
    }
    /* lt [lt] -> r1150 */
    for (long i23821 = 0; i23821 < 30; ++i23821) {
        r1150[i23821] = r1147[i23821] < r40[0] ? 1 : 0;
    }
    /* sub [sub] -> r1151 */
    for (long i23822 = 0; i23822 < 30; ++i23822) {
        r1151[i23822] = sub32(r1135[i23822], r1146[i23822]);
    }
    /* broadcast [broadcast_in_dim] -> r1152 */
    for (long i23823 = 0; i23823 < 30; ++i23823) {
        long t23825 = i23823;
        long c238240 = t23825 / 30; t23825 %= 30;
        long c238241 = t23825;
        r1152[i23823] = r1150[c238241 * 1];
    }
    /* select_n [select_n] -> r1153 */
    for (long i23826 = 0; i23826 < 30; ++i23826) {
        r1153[i23826] = r1152[i23826] == 0 ? r1135[i23826] : (r1151[i23826]);
    }
    /* broadcast [broadcast_in_dim] -> r1154 */
    for (long i23827 = 0; i23827 < 30; ++i23827) {
        long t23829 = i23827;
        long c238280 = t23829 / 30; t23829 %= 30;
        long c238281 = t23829;
        r1154[i23827] = r1148[c238281 * 1];
    }
    /* select_n [select_n] -> r1155 */
    for (long i23830 = 0; i23830 < 30; ++i23830) {
        r1155[i23830] = r1154[i23830] == 0 ? r1153[i23830] : (r1149[i23830]);
    }
    /* convert [convert_element_type] -> r1156 */
    for (long i23831 = 0; i23831 < 1; ++i23831) {
        r1156[i23831] = (int32_t)r222[0];
    }
    /* max [max] -> r1157 */
    for (long i23832 = 0; i23832 < 30; ++i23832) {
        r1157[i23832] = max32(r1156[0], r1155[i23832]);
    }
    /* convert [convert_element_type] -> r1158 */
    for (long i23833 = 0; i23833 < 1; ++i23833) {
        r1158[i23833] = (int32_t)r223[0];
    }
    /* min [min] -> r1159 */
    for (long i23834 = 0; i23834 < 30; ++i23834) {
        r1159[i23834] = min32(r1158[0], r1157[i23834]);
    }
    /* shl [shift_left] -> r1160 */
    for (long i23835 = 0; i23835 < 30; ++i23835) {
        r1160[i23835] = shl32(r1159[i23835], 1);
    }
    /* broadcast [broadcast_in_dim] -> r1161 */
    for (long i23836 = 0; i23836 < 30; ++i23836) {
        long t23838 = i23836;
        long c238370 = t23838 / 30; t23838 %= 30;
        long c238371 = t23838 / 1; t23838 %= 1;
        long c238372 = t23838;
        r1161[i23836] = r1160[c238371 * 1];
    }
    /* broadcast [broadcast_in_dim] -> r1162 */
    for (long i23839 = 0; i23839 < 30; ++i23839) {
        long t23841 = i23839;
        long c238400 = t23841 / 30; t23841 %= 30;
        long c238401 = t23841 / 1; t23841 %= 1;
        long c238402 = t23841;
        r1162[i23839] = r1160[c238401 * 1];
    }
    /* neg [neg] -> r1163 */
    for (long i23842 = 0; i23842 < 30; ++i23842) {
        r1163[i23842] = neg32(r1162[i23842]);
    }
    /* mov [device_put] -> r1164 */
    memcpy(r1164, r23, sizeof(int32_t) * 300);
    /* mov [device_put] -> r1165 */
    memcpy(r1165, r24, sizeof(int32_t) * 300);
    /* broadcast [broadcast_in_dim] -> r1166 */
    for (long i23843 = 0; i23843 < 300; ++i23843) {
        long t23845 = i23843;
        long c238440 = t23845 / 300; t23845 %= 300;
        long c238441 = t23845 / 10; t23845 %= 10;
        long c238442 = t23845;
        r1166[i23843] = r1164[c238441 * 10 + c238442 * 1];
    }
    /* add [add] -> r1167 */
    for (long i23846 = 0; i23846 < 300; ++i23846) {
        long t23848 = i23846;
        long c238470 = t23848 / 300; t23848 %= 300;
        long c238471 = t23848 / 10; t23848 %= 10;
        long c238472 = t23848;
        r1167[i23846] = add32(r1166[c238471 * 10 + c238472 * 1], r1161[c238471 * 1]);
    }
    /* convert [convert_element_type] -> r1168 */
    for (long i23849 = 0; i23849 < 1; ++i23849) {
        r1168[i23849] = (int32_t)r49[0];
    }
    /* max [max] -> r1169 */
    for (long i23850 = 0; i23850 < 300; ++i23850) {
        r1169[i23850] = max32(r1168[0], r1167[i23850]);
    }
    /* convert [convert_element_type] -> r1170 */
    for (long i23851 = 0; i23851 < 1; ++i23851) {
        r1170[i23851] = (int32_t)r50[0];
    }
    /* min [min] -> r1171 */
    for (long i23852 = 0; i23852 < 300; ++i23852) {
        r1171[i23852] = min32(r1170[0], r1169[i23852]);
    }
    /* broadcast [broadcast_in_dim] -> r1172 */
    for (long i23853 = 0; i23853 < 300; ++i23853) {
        long t23855 = i23853;
        long c238540 = t23855 / 300; t23855 %= 300;
        long c238541 = t23855 / 10; t23855 %= 10;
        long c238542 = t23855;
        r1172[i23853] = r1165[c238541 * 10 + c238542 * 1];
    }
    /* add [add] -> r1173 */
    for (long i23856 = 0; i23856 < 300; ++i23856) {
        long t23858 = i23856;
        long c238570 = t23858 / 300; t23858 %= 300;
        long c238571 = t23858 / 10; t23858 %= 10;
        long c238572 = t23858;
        r1173[i23856] = add32(r1172[c238571 * 10 + c238572 * 1], r1163[c238571 * 1]);
    }
    /* convert [convert_element_type] -> r1174 */
    for (long i23859 = 0; i23859 < 1; ++i23859) {
        r1174[i23859] = (int32_t)r49[0];
    }
    /* max [max] -> r1175 */
    for (long i23860 = 0; i23860 < 300; ++i23860) {
        r1175[i23860] = max32(r1174[0], r1173[i23860]);
    }
    /* convert [convert_element_type] -> r1176 */
    for (long i23861 = 0; i23861 < 1; ++i23861) {
        r1176[i23861] = (int32_t)r50[0];
    }
    /* min [min] -> r1177 */
    for (long i23862 = 0; i23862 < 300; ++i23862) {
        r1177[i23862] = min32(r1176[0], r1175[i23862]);
    }
    /* concat [concatenate] -> r1178 */
    for (long i23863 = 0; i23863 < 300; ++i23863) {
        long t23865 = i23863;
        long c238640 = t23865 / 300; t23865 %= 300;
        long c238641 = t23865 / 10; t23865 %= 10;
        long c238642 = t23865;
        r1178[c238640 * 600 + (c238641 + 0) * 10 + c238642 * 1] = r1171[i23863];
    }
    for (long i23866 = 0; i23866 < 300; ++i23866) {
        long t23868 = i23866;
        long c238670 = t23868 / 300; t23868 %= 300;
        long c238671 = t23868 / 10; t23868 %= 10;
        long c238672 = t23868;
        r1178[c238670 * 600 + (c238671 + 30) * 10 + c238672 * 1] = r1177[i23866];
    }
    /* mov [device_put] -> r1179 */
    memcpy(r1179, r25, sizeof(int32_t) * 10);
    /* broadcast [broadcast_in_dim] -> r1180 */
    for (long i23869 = 0; i23869 < 10; ++i23869) {
        long t23871 = i23869;
        long c238700 = t23871 / 10; t23871 %= 10;
        long c238701 = t23871 / 10; t23871 %= 10;
        long c238702 = t23871;
        r1180[i23869] = r1179[c238702 * 1];
    }
    /* concat [concatenate] -> r1181 */
    for (long i23872 = 0; i23872 < 600; ++i23872) {
        long t23874 = i23872;
        long c238730 = t23874 / 600; t23874 %= 600;
        long c238731 = t23874 / 10; t23874 %= 10;
        long c238732 = t23874;
        r1181[c238730 * 610 + (c238731 + 0) * 10 + c238732 * 1] = r1178[i23872];
    }
    for (long i23875 = 0; i23875 < 10; ++i23875) {
        long t23877 = i23875;
        long c238760 = t23877 / 10; t23877 %= 10;
        long c238761 = t23877 / 10; t23877 %= 10;
        long c238762 = t23877;
        r1181[c238760 * 610 + (c238761 + 60) * 10 + c238762 * 1] = r1180[i23875];
    }
    /* transpose [transpose] -> r1182 */
    for (long i23878 = 0; i23878 < 610; ++i23878) {
        long t23880 = i23878;
        long c238790 = t23880 / 610; t23880 %= 610;
        long c238791 = t23880 / 61; t23880 %= 61;
        long c238792 = t23880;
        r1182[i23878] = r1181[c238790 * 610 + c238791 * 1 + c238792 * 10];
    }
    /* reduce_max [reduce_max] -> r1183 */
    for (long i23881 = 0; i23881 < 10; ++i23881) {
        r1183[i23881] = (-2147483647 - 1);
    }
    for (long i23882 = 0; i23882 < 610; ++i23882) {
        long t23884 = i23882;
        long c238830 = t23884 / 610; t23884 %= 610;
        long c238831 = t23884 / 61; t23884 %= 61;
        long c238832 = t23884;
        r1183[c238830 * 10 + c238831 * 1] = max32(r1183[c238830 * 10 + c238831 * 1], r1182[i23882]);
    }
    /* sub [sub] -> r1185 */
    for (long i23885 = 0; i23885 < 10; ++i23885) {
        r1185[i23885] = sub32(r1183[i23885], r1184[0]);
    }
    /* loop [scan] -> r1201 */
    memcpy(r1186, r1182, sizeof(int32_t) * 610);
    memcpy(r1187, r1184, sizeof(int32_t) * 1);
    memcpy(r1188, r40, sizeof(int32_t) * 1);
    memcpy(r1189, r1185, sizeof(int32_t) * 10);
    memcpy(r1190, r1183, sizeof(int32_t) * 10);
    for (long t23886 = 0; t23886 < 11; ++t23886) {
        /* add [add] -> r1191 */
        for (long i24887 = 0; i24887 < 1; ++i24887) {
            r1191[i24887] = add32(r1188[0], r30[0]);
        }
        /* add [add] -> r1192 */
        for (long i24888 = 0; i24888 < 10; ++i24888) {
            r1192[i24888] = add32(r1189[i24888], r1190[i24888]);
        }
        /* shra [shift_right_arithmetic] -> r1193 */
        for (long i24889 = 0; i24889 < 10; ++i24889) {
            r1193[i24889] = asr32(r1192[i24889], 1);
        }
        /* broadcast [broadcast_in_dim] -> r1194 */
        for (long i24890 = 0; i24890 < 10; ++i24890) {
            long t24892 = i24890;
            long c248910 = t24892 / 10; t24892 %= 10;
            long c248911 = t24892 / 1; t24892 %= 1;
            long c248912 = t24892;
            r1194[i24890] = r1193[c248911 * 1];
        }
        /* sub [sub] -> r1195 */
        for (long i24893 = 0; i24893 < 610; ++i24893) {
            long t24895 = i24893;
            long c248940 = t24895 / 610; t24895 %= 610;
            long c248941 = t24895 / 61; t24895 %= 61;
            long c248942 = t24895;
            r1195[i24893] = sub32(r1186[c248941 * 61 + c248942 * 1], r1194[c248941 * 1]);
        }
        /* max [max] -> r1196 */
        for (long i24896 = 0; i24896 < 610; ++i24896) {
            r1196[i24896] = max32(r1195[i24896], r40[0]);
        }
        /* reduce_sum [reduce_sum] -> r1197 */
        for (long i24897 = 0; i24897 < 10; ++i24897) {
            r1197[i24897] = 0;
        }
        for (long i24898 = 0; i24898 < 610; ++i24898) {
            long t24900 = i24898;
            long c248990 = t24900 / 610; t24900 %= 610;
            long c248991 = t24900 / 61; t24900 %= 61;
            long c248992 = t24900;
            r1197[c248990 * 10 + c248991 * 1] = add32(r1197[c248990 * 10 + c248991 * 1], r1196[i24898]);
        }
        /* gt [gt] -> r1198 */
        for (long i24901 = 0; i24901 < 10; ++i24901) {
            r1198[i24901] = r1197[i24901] > r1187[0] ? 1 : 0;
        }
        /* select_n [select_n] -> r1199 */
        for (long i24902 = 0; i24902 < 10; ++i24902) {
            r1199[i24902] = r1198[i24902] == 0 ? r1189[i24902] : (r1193[i24902]);
        }
        /* select_n [select_n] -> r1200 */
        for (long i24903 = 0; i24903 < 10; ++i24903) {
            r1200[i24903] = r1198[i24903] == 0 ? r1193[i24903] : (r1190[i24903]);
        }
        memcpy(r1188, r1191, sizeof(int32_t) * 1);
        memcpy(r1189, r1199, sizeof(int32_t) * 10);
        memcpy(r1190, r1200, sizeof(int32_t) * 10);
    }
    memcpy(r1201, r1188, sizeof(int32_t) * 1);
    memcpy(r1202, r1189, sizeof(int32_t) * 10);
    memcpy(r1203, r1190, sizeof(int32_t) * 10);
    /* broadcast [broadcast_in_dim] -> r1204 */
    for (long i24904 = 0; i24904 < 300; ++i24904) {
        long t24906 = i24904;
        long c249050 = t24906 / 300; t24906 %= 300;
        long c249051 = t24906 / 10; t24906 %= 10;
        long c249052 = t24906;
        r1204[i24904] = r1165[c249051 * 10 + c249052 * 1];
    }
    /* add [add] -> r1205 */
    for (long i24907 = 0; i24907 < 300; ++i24907) {
        long t24909 = i24907;
        long c249080 = t24909 / 300; t24909 %= 300;
        long c249081 = t24909 / 10; t24909 %= 10;
        long c249082 = t24909;
        r1205[i24907] = add32(r1204[c249081 * 10 + c249082 * 1], r1161[c249081 * 1]);
    }
    /* convert [convert_element_type] -> r1206 */
    for (long i24910 = 0; i24910 < 1; ++i24910) {
        r1206[i24910] = (int32_t)r49[0];
    }
    /* max [max] -> r1207 */
    for (long i24911 = 0; i24911 < 300; ++i24911) {
        r1207[i24911] = max32(r1206[0], r1205[i24911]);
    }
    /* convert [convert_element_type] -> r1208 */
    for (long i24912 = 0; i24912 < 1; ++i24912) {
        r1208[i24912] = (int32_t)r50[0];
    }
    /* min [min] -> r1209 */
    for (long i24913 = 0; i24913 < 300; ++i24913) {
        r1209[i24913] = min32(r1208[0], r1207[i24913]);
    }
    /* broadcast [broadcast_in_dim] -> r1210 */
    for (long i24914 = 0; i24914 < 300; ++i24914) {
        long t24916 = i24914;
        long c249150 = t24916 / 300; t24916 %= 300;
        long c249151 = t24916 / 10; t24916 %= 10;
        long c249152 = t24916;
        r1210[i24914] = r1164[c249151 * 10 + c249152 * 1];
    }
    /* add [add] -> r1211 */
    for (long i24917 = 0; i24917 < 300; ++i24917) {
        long t24919 = i24917;
        long c249180 = t24919 / 300; t24919 %= 300;
        long c249181 = t24919 / 10; t24919 %= 10;
        long c249182 = t24919;
        r1211[i24917] = add32(r1210[c249181 * 10 + c249182 * 1], r1163[c249181 * 1]);
    }
    /* convert [convert_element_type] -> r1212 */
    for (long i24920 = 0; i24920 < 1; ++i24920) {
        r1212[i24920] = (int32_t)r49[0];
    }
    /* max [max] -> r1213 */
    for (long i24921 = 0; i24921 < 300; ++i24921) {
        r1213[i24921] = max32(r1212[0], r1211[i24921]);
    }
    /* convert [convert_element_type] -> r1214 */
    for (long i24922 = 0; i24922 < 1; ++i24922) {
        r1214[i24922] = (int32_t)r50[0];
    }
    /* min [min] -> r1215 */
    for (long i24923 = 0; i24923 < 300; ++i24923) {
        r1215[i24923] = min32(r1214[0], r1213[i24923]);
    }
    /* concat [concatenate] -> r1216 */
    for (long i24924 = 0; i24924 < 300; ++i24924) {
        long t24926 = i24924;
        long c249250 = t24926 / 300; t24926 %= 300;
        long c249251 = t24926 / 10; t24926 %= 10;
        long c249252 = t24926;
        r1216[c249250 * 600 + (c249251 + 0) * 10 + c249252 * 1] = r1209[i24924];
    }
    for (long i24927 = 0; i24927 < 300; ++i24927) {
        long t24929 = i24927;
        long c249280 = t24929 / 300; t24929 %= 300;
        long c249281 = t24929 / 10; t24929 %= 10;
        long c249282 = t24929;
        r1216[c249280 * 600 + (c249281 + 30) * 10 + c249282 * 1] = r1215[i24927];
    }
    /* mov [device_put] -> r1217 */
    memcpy(r1217, r25, sizeof(int32_t) * 10);
    /* broadcast [broadcast_in_dim] -> r1218 */
    for (long i24930 = 0; i24930 < 10; ++i24930) {
        long t24932 = i24930;
        long c249310 = t24932 / 10; t24932 %= 10;
        long c249311 = t24932 / 10; t24932 %= 10;
        long c249312 = t24932;
        r1218[i24930] = r1217[c249312 * 1];
    }
    /* concat [concatenate] -> r1219 */
    for (long i24933 = 0; i24933 < 600; ++i24933) {
        long t24935 = i24933;
        long c249340 = t24935 / 600; t24935 %= 600;
        long c249341 = t24935 / 10; t24935 %= 10;
        long c249342 = t24935;
        r1219[c249340 * 610 + (c249341 + 0) * 10 + c249342 * 1] = r1216[i24933];
    }
    for (long i24936 = 0; i24936 < 10; ++i24936) {
        long t24938 = i24936;
        long c249370 = t24938 / 10; t24938 %= 10;
        long c249371 = t24938 / 10; t24938 %= 10;
        long c249372 = t24938;
        r1219[c249370 * 610 + (c249371 + 60) * 10 + c249372 * 1] = r1218[i24936];
    }
    /* transpose [transpose] -> r1220 */
    for (long i24939 = 0; i24939 < 610; ++i24939) {
        long t24941 = i24939;
        long c249400 = t24941 / 610; t24941 %= 610;
        long c249401 = t24941 / 61; t24941 %= 61;
        long c249402 = t24941;
        r1220[i24939] = r1219[c249400 * 610 + c249401 * 1 + c249402 * 10];
    }
    /* reduce_max [reduce_max] -> r1221 */
    for (long i24942 = 0; i24942 < 10; ++i24942) {
        r1221[i24942] = (-2147483647 - 1);
    }
    for (long i24943 = 0; i24943 < 610; ++i24943) {
        long t24945 = i24943;
        long c249440 = t24945 / 610; t24945 %= 610;
        long c249441 = t24945 / 61; t24945 %= 61;
        long c249442 = t24945;
        r1221[c249440 * 10 + c249441 * 1] = max32(r1221[c249440 * 10 + c249441 * 1], r1220[i24943]);
    }
    /* sub [sub] -> r1222 */
    for (long i24946 = 0; i24946 < 10; ++i24946) {
        r1222[i24946] = sub32(r1221[i24946], r1184[0]);
    }
    /* loop [scan] -> r1238 */
    memcpy(r1223, r1220, sizeof(int32_t) * 610);
    memcpy(r1224, r1184, sizeof(int32_t) * 1);
    memcpy(r1225, r40, sizeof(int32_t) * 1);
    memcpy(r1226, r1222, sizeof(int32_t) * 10);
    memcpy(r1227, r1221, sizeof(int32_t) * 10);
    for (long t24947 = 0; t24947 < 11; ++t24947) {
        /* add [add] -> r1228 */
        for (long i25948 = 0; i25948 < 1; ++i25948) {
            r1228[i25948] = add32(r1225[0], r30[0]);
        }
        /* add [add] -> r1229 */
        for (long i25949 = 0; i25949 < 10; ++i25949) {
            r1229[i25949] = add32(r1226[i25949], r1227[i25949]);
        }
        /* shra [shift_right_arithmetic] -> r1230 */
        for (long i25950 = 0; i25950 < 10; ++i25950) {
            r1230[i25950] = asr32(r1229[i25950], 1);
        }
        /* broadcast [broadcast_in_dim] -> r1231 */
        for (long i25951 = 0; i25951 < 10; ++i25951) {
            long t25953 = i25951;
            long c259520 = t25953 / 10; t25953 %= 10;
            long c259521 = t25953 / 1; t25953 %= 1;
            long c259522 = t25953;
            r1231[i25951] = r1230[c259521 * 1];
        }
        /* sub [sub] -> r1232 */
        for (long i25954 = 0; i25954 < 610; ++i25954) {
            long t25956 = i25954;
            long c259550 = t25956 / 610; t25956 %= 610;
            long c259551 = t25956 / 61; t25956 %= 61;
            long c259552 = t25956;
            r1232[i25954] = sub32(r1223[c259551 * 61 + c259552 * 1], r1231[c259551 * 1]);
        }
        /* max [max] -> r1233 */
        for (long i25957 = 0; i25957 < 610; ++i25957) {
            r1233[i25957] = max32(r1232[i25957], r40[0]);
        }
        /* reduce_sum [reduce_sum] -> r1234 */
        for (long i25958 = 0; i25958 < 10; ++i25958) {
            r1234[i25958] = 0;
        }
        for (long i25959 = 0; i25959 < 610; ++i25959) {
            long t25961 = i25959;
            long c259600 = t25961 / 610; t25961 %= 610;
            long c259601 = t25961 / 61; t25961 %= 61;
            long c259602 = t25961;
            r1234[c259600 * 10 + c259601 * 1] = add32(r1234[c259600 * 10 + c259601 * 1], r1233[i25959]);
        }
        /* gt [gt] -> r1235 */
        for (long i25962 = 0; i25962 < 10; ++i25962) {
            r1235[i25962] = r1234[i25962] > r1224[0] ? 1 : 0;
        }
        /* select_n [select_n] -> r1236 */
        for (long i25963 = 0; i25963 < 10; ++i25963) {
            r1236[i25963] = r1235[i25963] == 0 ? r1226[i25963] : (r1230[i25963]);
        }
        /* select_n [select_n] -> r1237 */
        for (long i25964 = 0; i25964 < 10; ++i25964) {
            r1237[i25964] = r1235[i25964] == 0 ? r1230[i25964] : (r1227[i25964]);
        }
        memcpy(r1225, r1228, sizeof(int32_t) * 1);
        memcpy(r1226, r1236, sizeof(int32_t) * 10);
        memcpy(r1227, r1237, sizeof(int32_t) * 10);
    }
    memcpy(r1238, r1225, sizeof(int32_t) * 1);
    memcpy(r1239, r1226, sizeof(int32_t) * 10);
    memcpy(r1240, r1227, sizeof(int32_t) * 10);
    /* broadcast [broadcast_in_dim] -> r1241 */
    for (long i25965 = 0; i25965 < 10; ++i25965) {
        long t25967 = i25965;
        long c259660 = t25967 / 10; t25967 %= 10;
        long c259661 = t25967 / 1; t25967 %= 1;
        long c259662 = t25967;
        r1241[i25965] = r1203[c259661 * 1];
    }
    /* broadcast [broadcast_in_dim] -> r1242 */
    for (long i25968 = 0; i25968 < 10; ++i25968) {
        long t25970 = i25968;
        long c259690 = t25970 / 10; t25970 %= 10;
        long c259691 = t25970 / 1; t25970 %= 1;
        long c259692 = t25970;
        r1242[i25968] = r1240[c259691 * 1];
    }
    /* concat [concatenate] -> r1243 */
    for (long i25971 = 0; i25971 < 10; ++i25971) {
        long t25973 = i25971;
        long c259720 = t25973 / 10; t25973 %= 10;
        long c259721 = t25973 / 1; t25973 %= 1;
        long c259722 = t25973;
        r1243[c259720 * 20 + c259721 * 2 + (c259722 + 0) * 1] = r1241[i25971];
    }
    for (long i25974 = 0; i25974 < 10; ++i25974) {
        long t25976 = i25974;
        long c259750 = t25976 / 10; t25976 %= 10;
        long c259751 = t25976 / 1; t25976 %= 1;
        long c259752 = t25976;
        r1243[c259750 * 20 + c259751 * 2 + (c259752 + 1) * 1] = r1242[i25974];
    }
    /* reduce_max [reduce_max] -> r1244 */
    for (long i25977 = 0; i25977 < 10; ++i25977) {
        r1244[i25977] = (-2147483647 - 1);
    }
    for (long i25978 = 0; i25978 < 20; ++i25978) {
        long t25980 = i25978;
        long c259790 = t25980 / 20; t25980 %= 20;
        long c259791 = t25980 / 2; t25980 %= 2;
        long c259792 = t25980;
        r1244[c259790 * 10 + c259791 * 1] = max32(r1244[c259790 * 10 + c259791 * 1], r1243[i25978]);
    }
    /* sub [sub] -> r1246 */
    for (long i25981 = 0; i25981 < 10; ++i25981) {
        r1246[i25981] = sub32(r1244[i25981], r1245[0]);
    }
    /* loop [scan] -> r1262 */
    memcpy(r1247, r1243, sizeof(int32_t) * 20);
    memcpy(r1248, r1245, sizeof(int32_t) * 1);
    memcpy(r1249, r40, sizeof(int32_t) * 1);
    memcpy(r1250, r1246, sizeof(int32_t) * 10);
    memcpy(r1251, r1244, sizeof(int32_t) * 10);
    for (long t25982 = 0; t25982 < 8; ++t25982) {
        /* add [add] -> r1252 */
        for (long i26983 = 0; i26983 < 1; ++i26983) {
            r1252[i26983] = add32(r1249[0], r30[0]);
        }
        /* add [add] -> r1253 */
        for (long i26984 = 0; i26984 < 10; ++i26984) {
            r1253[i26984] = add32(r1250[i26984], r1251[i26984]);
        }
        /* shra [shift_right_arithmetic] -> r1254 */
        for (long i26985 = 0; i26985 < 10; ++i26985) {
            r1254[i26985] = asr32(r1253[i26985], 1);
        }
        /* broadcast [broadcast_in_dim] -> r1255 */
        for (long i26986 = 0; i26986 < 10; ++i26986) {
            long t26988 = i26986;
            long c269870 = t26988 / 10; t26988 %= 10;
            long c269871 = t26988 / 1; t26988 %= 1;
            long c269872 = t26988;
            r1255[i26986] = r1254[c269871 * 1];
        }
        /* sub [sub] -> r1256 */
        for (long i26989 = 0; i26989 < 20; ++i26989) {
            long t26991 = i26989;
            long c269900 = t26991 / 20; t26991 %= 20;
            long c269901 = t26991 / 2; t26991 %= 2;
            long c269902 = t26991;
            r1256[i26989] = sub32(r1247[c269901 * 2 + c269902 * 1], r1255[c269901 * 1]);
        }
        /* max [max] -> r1257 */
        for (long i26992 = 0; i26992 < 20; ++i26992) {
            r1257[i26992] = max32(r1256[i26992], r40[0]);
        }
        /* reduce_sum [reduce_sum] -> r1258 */
        for (long i26993 = 0; i26993 < 10; ++i26993) {
            r1258[i26993] = 0;
        }
        for (long i26994 = 0; i26994 < 20; ++i26994) {
            long t26996 = i26994;
            long c269950 = t26996 / 20; t26996 %= 20;
            long c269951 = t26996 / 2; t26996 %= 2;
            long c269952 = t26996;
            r1258[c269950 * 10 + c269951 * 1] = add32(r1258[c269950 * 10 + c269951 * 1], r1257[i26994]);
        }
        /* gt [gt] -> r1259 */
        for (long i26997 = 0; i26997 < 10; ++i26997) {
            r1259[i26997] = r1258[i26997] > r1248[0] ? 1 : 0;
        }
        /* select_n [select_n] -> r1260 */
        for (long i26998 = 0; i26998 < 10; ++i26998) {
            r1260[i26998] = r1259[i26998] == 0 ? r1250[i26998] : (r1254[i26998]);
        }
        /* select_n [select_n] -> r1261 */
        for (long i26999 = 0; i26999 < 10; ++i26999) {
            r1261[i26999] = r1259[i26999] == 0 ? r1254[i26999] : (r1251[i26999]);
        }
        memcpy(r1249, r1252, sizeof(int32_t) * 1);
        memcpy(r1250, r1260, sizeof(int32_t) * 10);
        memcpy(r1251, r1261, sizeof(int32_t) * 10);
    }
    memcpy(r1262, r1249, sizeof(int32_t) * 1);
    memcpy(r1263, r1250, sizeof(int32_t) * 10);
    memcpy(r1264, r1251, sizeof(int32_t) * 10);
    /* sub [sub] -> r1265 */
    for (long i27000 = 0; i27000 < 10; ++i27000) {
        r1265[i27000] = sub32(r1203[i27000], r1264[i27000]);
    }
    /* max [max] -> r1266 */
    for (long i27001 = 0; i27001 < 10; ++i27001) {
        r1266[i27001] = max32(r1265[i27001], r40[0]);
    }
    /* sub [sub] -> r1267 */
    for (long i27002 = 0; i27002 < 10; ++i27002) {
        r1267[i27002] = sub32(r1240[i27002], r1264[i27002]);
    }
    /* max [max] -> r1268 */
    for (long i27003 = 0; i27003 < 10; ++i27003) {
        r1268[i27003] = max32(r1267[i27003], r40[0]);
    }
    /* sub [sub] -> r1269 */
    for (long i27004 = 0; i27004 < 10; ++i27004) {
        r1269[i27004] = sub32(r1266[i27004], r1268[i27004]);
    }
}

int main(int argc, char **argv) {
    if (argc != 3) { fprintf(stderr, "usage: %s in.bin out.bin\n", argv[0]); return 2; }
    FILE *fi = fopen(argv[1], "rb");
    if (!fi) { perror("in"); return 2; }
    if (fread(r0, sizeof(int32_t), 15, fi) != 15) { fprintf(stderr, "short read\n"); return 2; }
    if (fread(r1, sizeof(int32_t), 15, fi) != 15) { fprintf(stderr, "short read\n"); return 2; }
    if (fread(r2, sizeof(int32_t), 15, fi) != 15) { fprintf(stderr, "short read\n"); return 2; }
    if (fread(r3, sizeof(int32_t), 15, fi) != 15) { fprintf(stderr, "short read\n"); return 2; }
    if (fread(r4, sizeof(int32_t), 15, fi) != 15) { fprintf(stderr, "short read\n"); return 2; }
    if (fread(r5, sizeof(int32_t), 15, fi) != 15) { fprintf(stderr, "short read\n"); return 2; }
    if (fread(r6, sizeof(int32_t), 1, fi) != 1) { fprintf(stderr, "short read\n"); return 2; }
    if (fread(r7, sizeof(int32_t), 1, fi) != 1) { fprintf(stderr, "short read\n"); return 2; }
    if (fread(r8, sizeof(int32_t), 1, fi) != 1) { fprintf(stderr, "short read\n"); return 2; }
    if (fread(r9, sizeof(int32_t), 1, fi) != 1) { fprintf(stderr, "short read\n"); return 2; }
    if (fread(r10, sizeof(int32_t), 1, fi) != 1) { fprintf(stderr, "short read\n"); return 2; }
    if (fread(r11, sizeof(int32_t), 1, fi) != 1) { fprintf(stderr, "short read\n"); return 2; }
    if (fread(r12, sizeof(int32_t), 30, fi) != 30) { fprintf(stderr, "short read\n"); return 2; }
    if (fread(r13, sizeof(int32_t), 1, fi) != 1) { fprintf(stderr, "short read\n"); return 2; }
    if (fread(r14, sizeof(int32_t), 1, fi) != 1) { fprintf(stderr, "short read\n"); return 2; }
    if (fread(r15, sizeof(uint8_t), 1, fi) != 1) { fprintf(stderr, "short read\n"); return 2; }
    if (fread(r16, sizeof(int32_t), 160, fi) != 160) { fprintf(stderr, "short read\n"); return 2; }
    if (fread(r17, sizeof(int32_t), 1, fi) != 1) { fprintf(stderr, "short read\n"); return 2; }
    fclose(fi);
    program_run();
    FILE *fo = fopen(argv[2], "wb");
    if (!fo) { perror("out"); return 2; }
    fwrite(r131, sizeof(int32_t), 15, fo);
    fwrite(r329, sizeof(int32_t), 15, fo);
    fwrite(r526, sizeof(int32_t), 15, fo);
    fwrite(r723, sizeof(int32_t), 15, fo);
    fwrite(r920, sizeof(int32_t), 15, fo);
    fwrite(r1117, sizeof(int32_t), 15, fo);
    fwrite(r132, sizeof(int32_t), 1, fo);
    fwrite(r330, sizeof(int32_t), 1, fo);
    fwrite(r527, sizeof(int32_t), 1, fo);
    fwrite(r724, sizeof(int32_t), 1, fo);
    fwrite(r921, sizeof(int32_t), 1, fo);
    fwrite(r1118, sizeof(int32_t), 1, fo);
    fwrite(r1120, sizeof(int32_t), 30, fo);
    fwrite(r28, sizeof(int32_t), 1, fo);
    fwrite(r1121, sizeof(int32_t), 1, fo);
    fwrite(r15, sizeof(uint8_t), 1, fo);
    fwrite(r1269, sizeof(int32_t), 10, fo);
    fwrite(r1159, sizeof(int32_t), 30, fo);
    fclose(fo);
    return 0;
}
