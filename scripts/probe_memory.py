"""Memory-term bisection on the production mesh: lower train/prefill
variants of glm4-9b and print memory_analysis + roofline terms."""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
import dataclasses
import sys

import jax

from repro.configs import get_arch
from repro.distributed import sharding as sh
from repro.distributed.steps import make_train_step
from repro.launch import specs as S
from repro.launch.dryrun import lower_cell, roofline
from repro.launch.mesh import make_production_mesh
from repro.optim import AdamWConfig

variants = {
    "train": dict(),
    "train_noremat": dict(remat=False),
    "train_L4": dict(num_layers=4),
    "train_L8": dict(num_layers=8),
    "prefill_like_train": None,  # forward only at train shapes
}

which = sys.argv[1:] or list(variants)
mesh = make_production_mesh()
for name in which:
    ov = variants[name]
    cfg = get_arch("glm4-9b")
    cell = S.SHAPES["train_4k"]
    if ov is None:
        cell = S.ShapeCell("p", 4096, 256, "prefill")
    else:
        cfg = dataclasses.replace(cfg, **ov)
    with mesh:
        lowered = lower_cell(cfg, cell, mesh)
        comp = lowered.compile()
    m = comp.memory_analysis()
    r = roofline(comp, comp.as_text(), 256, cfg, cell)
    print(f"{name}: temp={m.temp_size_in_bytes/2**30:.1f}GiB "
          f"args={m.argument_size_in_bytes/2**30:.1f}GiB "
          f"comp={r['compute_s']:.2f} mem={r['memory_s']:.2f} "
          f"coll={r['collective_s']:.2f} useful={r['useful_flops_ratio']:.3f}",
          flush=True)
