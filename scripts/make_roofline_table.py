"""Generate results/roofline_table.md from the dry-run JSONs."""
import json
import sys

paths = sys.argv[1:] or ["results/dryrun_single_pod.json"]
rows = []
for p in paths:
    rows.extend(json.load(open(p)))

out = []
out.append("| arch | shape | mesh | accum | compute_s | memory_s | "
           "collective_s | dominant | 6N·D / HLO | roofline frac | "
           "temp GiB | bottleneck note |")
out.append("|---|---|---|---|---|---|---|---|---|---|---|---|")

NOTES = {
    ("memory_s", "train"): "activation+param streaming; lower via bigger "
                           "per-chip batch or fp8 params",
    ("memory_s", "prefill"): "KV write + stream traffic; fuse attention "
                             "(Pallas) to cut score round-trips",
    ("memory_s", "decode"): "weight streaming dominates at batch/chip; "
                            "raise batch or quantize weights",
    ("compute_s", "train"): "MXU-bound: good; raise per-chip batch to "
                            "amortize collectives further",
    ("compute_s", "prefill"): "attention FLOPs; SWA/sparsity to cut",
    ("collective_s", "train"): "FSDP all-gather / grad reduce; overlap with "
                               "compute or shard less over data",
    ("collective_s", "prefill"): "TP all-reduces; larger model axis tiles",
    ("collective_s", "decode"): "per-token weight gathers; keep weights "
                                "resident (pure TP for serving)",
}

for r in rows:
    if r["status"] != "ok":
        out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | - | "
                   f"FAILED: {r['error'][:60]} | | | | | | | |")
        continue
    rf = r["roofline"]
    kind = ("train" if r["shape"].startswith("train") else
            "prefill" if "prefill" in r["shape"] else "decode")
    note = NOTES.get((rf["dominant"], kind), "")
    temp = r["memory"]["temp_bytes"] / 2**30
    out.append(
        f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
        f"{r.get('grad_accum', 1)} | "
        f"{rf['compute_s']:.3f} | {rf['memory_s']:.3f} | "
        f"{rf['collective_s']:.3f} | {rf['dominant'].replace('_s','')} | "
        f"{rf['useful_flops_ratio']:.2f} | {rf['roofline_fraction']:.3f} | "
        f"{temp:.1f} | {note} |")

text = "\n".join(out) + "\n"
open("results/roofline_table.md", "w").write(text)
print(text)
