#!/usr/bin/env python
"""Regenerate the golden regression fixtures in tests/golden/.

    PYTHONPATH=src python scripts/regen_golden.py [case ...]

Run this ONLY when a numerics change is intentional (new solver, new
reduction order, retuned filters) — commit the refreshed .npz files together
with the change and say why in the commit message. tests/test_golden.py
fails loudly when the recorded audio -> decision vectors drift.

The recorded surface includes the fixed-point hardware twin's INTEGER
codes (``*_fixed_q``): those gate at exact equality, so any change to the
integer datapath (specs, shift tables, bisection, CSD standardization)
must regenerate here and justify itself.
"""

from __future__ import annotations

import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tests"))

from golden_cases import CASES, GOLDEN_DIR, compute_outputs  # noqa: E402


def main(argv):
    names = argv or sorted(CASES)
    os.makedirs(GOLDEN_DIR, exist_ok=True)
    for name in names:
        case = CASES[name]
        out = compute_outputs(case)
        path = os.path.join(GOLDEN_DIR, f"{name}.npz")
        np.savez_compressed(path, **out)
        sizes = {k: v.shape for k, v in out.items()}
        print(f"wrote {path}: {sizes}")


if __name__ == "__main__":
    main(sys.argv[1:])
