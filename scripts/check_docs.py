#!/usr/bin/env python
"""Intra-repo markdown link checker (the tier-1 docs gate).

Scans README.md, ROADMAP.md and docs/*.md for markdown links and inline
file references, and fails when a RELATIVE target (no scheme, no anchor-only
link) does not exist on disk — so a renamed module or moved doc breaks
tier-1 instead of rotting silently. External http(s) links are not fetched.

    PYTHONPATH=src python scripts/check_docs.py
"""

from __future__ import annotations

import os
import re
import sys

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _sources() -> list:
    srcs = ["README.md", "ROADMAP.md"]
    docs = os.path.join(REPO, "docs")
    if os.path.isdir(docs):
        srcs += sorted(os.path.join("docs", f) for f in os.listdir(docs)
                       if f.endswith(".md"))
    return srcs

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")   # [text](target)


def check(path: str) -> list:
    errors = []
    base = os.path.dirname(os.path.join(REPO, path))
    in_fence = False
    with open(os.path.join(REPO, path)) as f:
        for lineno, line in enumerate(f, 1):
            if line.lstrip().startswith("```"):
                in_fence = not in_fence
                continue
            if in_fence:
                continue
            # inline code spans aren't links (`consumed[o](S,)` etc.)
            line = re.sub(r"`[^`]*`", "", line)
            for target in LINK_RE.findall(line):
                if "://" in target or target.startswith(("mailto:", "#")):
                    continue
                rel = target.split("#", 1)[0]
                if not rel:
                    continue
                if not os.path.exists(os.path.normpath(
                        os.path.join(base, rel))):
                    errors.append(f"{path}:{lineno}: broken link -> {target}")
    return errors


def main() -> int:
    sources = _sources()
    errors = []
    for src in sources:
        if os.path.exists(os.path.join(REPO, src)):
            errors.extend(check(src))
    for e in errors:
        print(e, file=sys.stderr)
    if errors:
        print(f"check_docs: {len(errors)} broken intra-repo link(s)",
              file=sys.stderr)
        return 1
    print(f"check_docs OK ({len(sources)} files)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
