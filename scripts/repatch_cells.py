"""Re-run specific dry-run cells (after targeted fixes) and merge the
records into the sweep JSONs."""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
import json
import sys

from repro.launch.dryrun import run_cell

CELLS = [
    ("mixtral-8x22b", "train_4k"),
    ("jamba-v0.1-52b", "train_4k"),
    ("mixtral-8x22b", "prefill_32k"),
    ("jamba-v0.1-52b", "prefill_32k"),
    ("deepseek-moe-16b", "train_4k"),
    ("deepseek-moe-16b", "prefill_32k"),
]

multi = "--multi-pod" in sys.argv
path = ("results/dryrun_multi_pod.json" if multi
        else "results/dryrun_single_pod.json")
records = json.load(open(path))
for arch, shape in CELLS:
    rec = run_cell(arch, shape, multi_pod=multi)
    status = rec["status"]
    t = rec.get("memory", {}).get("temp_bytes", 0) / 2**30
    print(f"{arch} {shape}: {status} temp={t:.1f}GiB", flush=True)
    for i, r in enumerate(records):
        if r["arch"] == arch and r["shape"] == shape:
            records[i] = rec
with open(path, "w") as f:
    json.dump(records, f, indent=1)
print("patched", path)
