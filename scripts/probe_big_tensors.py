"""Dump the largest tensor shapes in a compiled cell's HLO."""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
import dataclasses, re, sys
from collections import Counter
import jax
from repro.configs import get_arch
from repro.launch import specs as S
from repro.launch.dryrun import lower_cell
from repro.launch.mesh import make_production_mesh

arch = sys.argv[1] if len(sys.argv) > 1 else "glm4-9b"
nl = int(sys.argv[2]) if len(sys.argv) > 2 else 4
cfg = dataclasses.replace(get_arch(arch), num_layers=nl)
cell = S.SHAPES["train_4k"]
mesh = make_production_mesh()
with mesh:
    comp = lower_cell(cfg, cell, mesh).compile()
text = comp.as_text()
TYPES = {"f32": 4, "bf16": 2, "s32": 4, "pred": 1, "u32": 4, "s8": 1}
sizes = Counter()
for m in re.finditer(r"(\w+)\[([\d,]+)\]", text):
    dt, dims = m.group(1), m.group(2)
    if dt not in TYPES:
        continue
    n = 1
    for d in dims.split(","):
        n *= int(d)
    b = n * TYPES[dt]
    if b > 2**28:  # > 256MB
        sizes[f"{dt}[{dims}]"] += 1
print(f"{arch} L={nl}: temp={comp.memory_analysis().temp_size_in_bytes/2**30:.1f}GiB")
for shape, count in sorted(sizes.items(),
                           key=lambda kv: -eval(kv[0].split('[')[1][:-1].replace(',', '*'))
                           * TYPES[kv[0].split('[')[0]]):
    n = 1
    for d in shape.split("[")[1][:-1].split(","):
        n *= int(d)
    gb = n * TYPES[shape.split("[")[0]] / 2**30
    print(f"  {gb:8.2f} GiB x{count:4d}  {shape}")
